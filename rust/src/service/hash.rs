//! Structural kernel hashing: a process-independent digest of a
//! [`Kernel`] that is invariant under *renaming* of inames, arrays and
//! the kernel itself, but changes whenever the loop domain, grid
//! mapping, array declarations, accesses or operations change.
//!
//! The service's property cache ([`super::cache::SharedPropsCache`])
//! keys extracted [`crate::stats::KernelProps`] by this hash: two
//! requests carrying structurally identical inline kernels (or the same
//! named kernel) share one symbolic extraction, regardless of what the
//! client called its loops and buffers.
//!
//! Canonicalization: every [`Sym`] is replaced by its *position* —
//! parameters by index in `kernel.params`, inames by index in the
//! domain's dimension order, arrays by index in declaration order. All
//! structure is then folded into an FNV-1a 64-bit stream with
//! type/variant tags and length prefixes, so the encoding is
//! prefix-free and stable across processes (interning order never
//! leaks into the digest).

use crate::lpir::{Expr, IdxTag, Kernel};
use crate::qpoly::LinExpr;
use crate::util::fnv::Fnv64;
use crate::util::intern::Sym;
use std::collections::BTreeMap;

/// Canonical identity of a symbol within one kernel.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Canon {
    Param(usize),
    Iname(usize),
    /// not declared anywhere in the kernel (invalid kernels only);
    /// falls back to the raw name so hashing still terminates
    Free,
}

struct Canonicalizer {
    /// the *variable* namespace of index/bound expressions: params,
    /// shadowed by same-named domain dims. Array names deliberately do
    /// NOT live here — arrays occupy a separate namespace (the array
    /// position of an access), so an array that happens to share a
    /// variable's name cannot hijack its canonical identity.
    vars: BTreeMap<Sym, Canon>,
    /// array name -> declaration index
    arrays: BTreeMap<Sym, usize>,
}

impl Canonicalizer {
    fn new(kernel: &Kernel) -> Canonicalizer {
        let mut vars = BTreeMap::new();
        for (i, p) in kernel.params.iter().enumerate() {
            vars.insert(*p, Canon::Param(i));
        }
        // inserted after params: dims shadow same-named params
        for (i, d) in kernel.domain.dims.iter().enumerate() {
            vars.insert(d.name, Canon::Iname(i));
        }
        let arrays = kernel
            .arrays
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name, i))
            .collect();
        Canonicalizer { vars, arrays }
    }

    /// A symbol in variable position (LinExpr term, reduction iname,
    /// `within` entry).
    fn write_var(&self, h: &mut Fnv64, s: Sym) {
        match self.vars.get(&s).copied().unwrap_or(Canon::Free) {
            Canon::Param(i) => {
                h.write_u8(1).write_u64(i as u64);
            }
            Canon::Iname(i) => {
                h.write_u8(2).write_u64(i as u64);
            }
            Canon::Free => {
                h.write_u8(4).write_str(s.as_str());
            }
        }
    }

    /// A symbol in array position (the `array` of an access).
    fn write_array(&self, h: &mut Fnv64, s: Sym) {
        match self.arrays.get(&s) {
            Some(&i) => {
                h.write_u8(3).write_u64(i as u64);
            }
            // undeclared array (invalid kernels only): raw name
            None => {
                h.write_u8(4).write_str(s.as_str());
            }
        }
    }

    fn write_lin(&self, h: &mut Fnv64, e: &LinExpr) {
        // canonical term order: sort by canonical id, not by interning
        // order (BTreeMap<Sym, _> iterates in interning order, which is
        // process-history-dependent)
        let mut terms: Vec<(Canon, Sym, i64)> = e
            .terms
            .iter()
            .map(|(s, k)| (self.vars.get(s).copied().unwrap_or(Canon::Free), *s, *k))
            .collect();
        terms.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.as_str().cmp(b.1.as_str())));
        h.write_u64(terms.len() as u64);
        for (_, s, k) in terms {
            self.write_var(h, s);
            h.write_i64(k);
        }
        h.write_i64(e.c);
    }

    fn write_expr(&self, h: &mut Fnv64, e: &Expr) {
        match e {
            Expr::Lit(x) => {
                h.write_u8(10).write_f64(*x);
            }
            Expr::Idx(l) => {
                h.write_u8(11);
                self.write_lin(h, l);
            }
            Expr::Load(a) => {
                h.write_u8(12);
                self.write_array(h, a.array);
                h.write_u64(a.idx.len() as u64);
                for i in &a.idx {
                    self.write_lin(h, i);
                }
            }
            Expr::Un(op, x) => {
                h.write_u8(13).write_u8(*op as u8);
                self.write_expr(h, x);
            }
            Expr::Bin(op, a, b) => {
                h.write_u8(14).write_u8(*op as u8);
                self.write_expr(h, a);
                self.write_expr(h, b);
            }
            Expr::Cast(dt, x) => {
                h.write_u8(15).write_u8(*dt as u8);
                self.write_expr(h, x);
            }
            Expr::Reduce(op, iname, body) => {
                h.write_u8(16).write_u8(*op as u8);
                self.write_var(h, *iname);
                self.write_expr(h, body);
            }
        }
    }
}

fn tag_code(t: IdxTag) -> u8 {
    match t {
        IdxTag::Group(a) => 20 + (a as u8).min(7),
        IdxTag::Local(a) => 30 + (a as u8).min(7),
        IdxTag::Seq => 40,
        IdxTag::Unroll => 41,
    }
}

/// Structural digest of a kernel (see module docs). The kernel *name*
/// is deliberately excluded; callers that want per-name separation key
/// on `(name, hash)` themselves.
pub fn structural_hash(kernel: &Kernel) -> u64 {
    let c = Canonicalizer::new(kernel);
    let mut h = Fnv64::new();

    h.write_u64(kernel.params.len() as u64);

    // loop domain: each dim's bounds, tiling denominator, stride, and
    // its grid tag — by position, never by name
    h.write_u64(kernel.domain.dims.len() as u64);
    for d in &kernel.domain.dims {
        c.write_lin(&mut h, &d.lo);
        c.write_lin(&mut h, &d.hi.num);
        h.write_i64(d.hi.den);
        h.write_i64(d.step);
        h.write_u8(tag_code(kernel.tag(d.name)));
    }

    // arrays: dtype, shape, space, layout, output flag — by position
    h.write_u64(kernel.arrays.len() as u64);
    for a in &kernel.arrays {
        h.write_u8(a.dtype as u8);
        h.write_u64(a.shape.len() as u64);
        for s in &a.shape {
            c.write_lin(&mut h, s);
        }
        h.write_u8(a.space as u8);
        h.write_u8(a.layout as u8);
        h.write_u8(a.is_output as u8);
    }

    // instructions: lhs access, rhs tree, nest, deps, update flag
    h.write_u64(kernel.insns.len() as u64);
    for insn in &kernel.insns {
        h.write_u64(insn.id as u64);
        c.write_array(&mut h, insn.lhs.array);
        h.write_u64(insn.lhs.idx.len() as u64);
        for i in &insn.lhs.idx {
            c.write_lin(&mut h, i);
        }
        c.write_expr(&mut h, &insn.rhs);
        h.write_u64(insn.within.len() as u64);
        for w in &insn.within {
            c.write_var(&mut h, *w);
        }
        h.write_u64(insn.deps.len() as u64);
        for d in &insn.deps {
            h.write_u64(*d as u64);
        }
        h.write_u8(insn.is_update as u8);
    }

    h.finish()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::isl::{BoxDomain, Dim};
    use crate::lpir::builder::gid_lin_1d;
    use crate::lpir::{Access, ArrayDecl, DType, IdxTag, Insn, Kernel, Layout, MemSpace};
    use crate::qpoly::LinExpr;

    /// A copy kernel with caller-chosen iname/array names — the rename
    /// axis the hash must be invariant along.
    fn copy_kernel(g: &str, l: &str, a: &str, b: &str, lsize: i64) -> Kernel {
        let idx = LinExpr::scaled_var(g, lsize).add(&LinExpr::var(l));
        let k = Kernel {
            name: format!("copy_{g}_{a}"),
            params: vec!["n".into()],
            domain: BoxDomain::new(vec![
                Dim::tiles(g, LinExpr::var("n"), lsize),
                Dim::simple(l, LinExpr::constant(lsize)),
            ]),
            tags: [(g.into(), IdxTag::Group(0)), (l.into(), IdxTag::Local(0))]
                .into_iter()
                .collect(),
            arrays: vec![
                ArrayDecl {
                    name: a.into(),
                    dtype: DType::F32,
                    shape: vec![LinExpr::var("n")],
                    space: MemSpace::Global,
                    layout: Layout::RowMajor,
                    is_output: false,
                },
                ArrayDecl {
                    name: b.into(),
                    dtype: DType::F32,
                    shape: vec![LinExpr::var("n")],
                    space: MemSpace::Global,
                    layout: Layout::RowMajor,
                    is_output: true,
                },
            ],
            insns: vec![Insn {
                id: 0,
                lhs: Access { array: b.into(), idx: vec![idx.clone()] },
                rhs: Expr::Load(Access { array: a.into(), idx: vec![idx] }),
                within: vec![g.into(), l.into()],
                deps: vec![],
                is_update: false,
            }],
        };
        k.validate().unwrap();
        k
    }

    #[test]
    fn rename_invariant() {
        let base = structural_hash(&copy_kernel("g0", "l0", "a", "b", 256));
        // renamed inames, renamed arrays, renamed kernel: same structure
        assert_eq!(base, structural_hash(&copy_kernel("grp", "lane", "src", "dst", 256)));
        assert_eq!(base, structural_hash(&copy_kernel("g0", "l0", "x", "y", 256)));
    }

    #[test]
    fn array_names_live_in_their_own_namespace() {
        // an array that shares the param's name ("n") must not hijack
        // the param's canonical identity: renaming that array keeps the
        // hash, exactly like any other array rename
        let shadowed = structural_hash(&copy_kernel("g0", "l0", "n", "b", 256));
        assert_eq!(shadowed, structural_hash(&copy_kernel("g0", "l0", "a", "b", 256)));
        // and an array sharing an iname's name behaves the same
        let iname_shadow = structural_hash(&copy_kernel("g0", "l0", "l0_buf", "g0", 256));
        assert_eq!(iname_shadow, structural_hash(&copy_kernel("g0", "l0", "x", "y", 256)));
    }

    #[test]
    fn structural_changes_change_the_hash() {
        let base = structural_hash(&copy_kernel("g0", "l0", "a", "b", 256));
        // different group size -> different domain bounds
        assert_ne!(base, structural_hash(&copy_kernel("g0", "l0", "a", "b", 128)));
        // different access pattern
        let mut strided = copy_kernel("g0", "l0", "a", "b", 256);
        strided.insns[0].rhs = Expr::load("a", vec![gid_lin_1d(256).scale(2)]);
        assert_ne!(base, structural_hash(&strided));
        // extra operation on the rhs
        let mut scaled = copy_kernel("g0", "l0", "a", "b", 256);
        scaled.insns[0].rhs =
            Expr::mul(Expr::lit(2.0), Expr::load("a", vec![gid_lin_1d(256)]));
        assert_ne!(base, structural_hash(&scaled));
        // different literal constant
        let mut scaled3 = copy_kernel("g0", "l0", "a", "b", 256);
        scaled3.insns[0].rhs =
            Expr::mul(Expr::lit(3.0), Expr::load("a", vec![gid_lin_1d(256)]));
        assert_ne!(structural_hash(&scaled), structural_hash(&scaled3));
        // dtype change
        let mut f64k = copy_kernel("g0", "l0", "a", "b", 256);
        f64k.arrays[0].dtype = DType::F64;
        assert_ne!(base, structural_hash(&f64k));
        // update-vs-assign flag
        let mut upd = copy_kernel("g0", "l0", "a", "b", 256);
        upd.insns[0].is_update = true;
        assert_ne!(base, structural_hash(&upd));
        // grid tag change (sequential instead of local)
        let mut seq = copy_kernel("g0", "l0", "a", "b", 256);
        seq.tags.insert("l0".into(), IdxTag::Seq);
        assert_ne!(base, structural_hash(&seq));
    }

    #[test]
    fn builder_kernels_hash_deterministically() {
        // same builder invocation twice -> identical kernels -> equal hash
        let mk = || {
            crate::lpir::builder::KernelBuilder::new("scale", &["n"])
                .group_dims_1d(LinExpr::var("n"), 128)
                .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
                .global_array("o", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
                .insn(
                    Access::new("o", vec![gid_lin_1d(128)]),
                    Expr::mul(Expr::lit(3.0), Expr::load("a", vec![gid_lin_1d(128)])),
                    &["g0", "l0"],
                    &[],
                )
                .build()
                .unwrap()
        };
        assert_eq!(structural_hash(&mk()), structural_hash(&mk()));
    }
}
