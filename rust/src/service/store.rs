//! Persisted model artifacts: fitted per-device weight tables that can
//! be saved once (`uniperf fit --save models.json`) and queried millions
//! of times (`predict`/`serve`) without re-running a measurement
//! campaign.
//!
//! Each stored model carries three fingerprints so a stale artifact is
//! rejected instead of silently answering with wrong weights:
//!
//! * the **schema** fingerprint ([`crate::stats::Schema::fingerprint`]) —
//!   weight indices are meaningless if the property column layout moved;
//! * the **profile** fingerprint — the exact device profile the campaign
//!   ran against (any hardware-parameter edit invalidates the fit);
//! * the **suite** fingerprint — the capability-derived measurement
//!   suite (kernel structures, group shapes, size cases) the weights
//!   were fitted on.
//!
//! [`ModelStore::validate_against`] recomputes all three against the
//! *current* registry/schema at load time; `serve`/`predict` refuse to
//! start on any mismatch.

use crate::gpusim::DeviceProfile;
use crate::kernels;
use crate::perfmodel::Model;
use crate::stats::{ExtractOpts, Schema};
use crate::util::fnv::Fnv64;
use crate::util::json::Json;
use std::path::Path;

/// The artifact format this build writes and reads.
pub const FORMAT: &str = "uniperf-models-v1";

/// Version-tag gate shared by every persisted artifact (model store,
/// extraction cache): a future v2 file fails with a clear format
/// message instead of a fingerprint riddle, and a tagless blob is
/// refused outright.
pub(crate) fn check_format(j: &Json, expected: &str, what: &str) -> Result<(), String> {
    match j.get_str("format") {
        Some(f) if f == expected => Ok(()),
        Some(other) => Err(format!(
            "unsupported {what} format '{other}' (this build reads '{expected}')"
        )),
        None => Err(format!("{what}: missing 'format' (expected '{expected}')")),
    }
}

/// Digest of a device profile (exact JSON form, every field).
pub fn profile_fingerprint(p: &DeviceProfile) -> String {
    let mut h = Fnv64::new();
    h.write_str(&p.to_json().compact());
    h.hex()
}

/// Digest of the capability-derived measurement suite for a profile:
/// per case, the label, group shape, the parameter-binding digest
/// ([`super::cache::env_fingerprint`]) and the structural kernel hash.
pub fn suite_fingerprint(p: &DeviceProfile) -> String {
    let mut h = Fnv64::new();
    let cases = kernels::measurement_suite(p);
    h.write_u64(cases.len() as u64);
    for case in &cases {
        h.write_str(&case.label);
        h.write_i64(case.group.0);
        h.write_i64(case.group.1);
        h.write_u64(super::cache::env_fingerprint(&case.env));
        h.write_u64(super::hash::structural_hash(&case.kernel));
    }
    h.hex()
}

/// One device's persisted fit.
#[derive(Clone, Debug)]
pub struct StoredModel {
    pub model: Model,
    pub launch_overhead_s: f64,
    pub n_measurement_cases: usize,
    pub profile_fp: String,
    pub suite_fp: String,
}

impl StoredModel {
    /// Assemble from a fitted model + the profile it was fitted on.
    pub fn new(
        model: Model,
        launch_overhead_s: f64,
        n_measurement_cases: usize,
        profile: &DeviceProfile,
    ) -> StoredModel {
        StoredModel {
            model,
            launch_overhead_s,
            n_measurement_cases,
            profile_fp: profile_fingerprint(profile),
            suite_fp: suite_fingerprint(profile),
        }
    }

    pub fn device(&self) -> &str {
        &self.model.device
    }
}

/// A set of persisted per-device models (the `models.json` artifact).
#[derive(Clone, Debug)]
pub struct ModelStore {
    /// fingerprint of the schema the weight vectors are laid out in
    pub schema_fp: String,
    /// the extraction options every model in this store was fitted
    /// under — serving with different options would evaluate property
    /// vectors the weights were never fitted against, so the service
    /// refuses a mismatch at construction
    pub extract: ExtractOpts,
    models: Vec<StoredModel>,
}

impl ModelStore {
    pub fn new(schema: &Schema, extract: ExtractOpts) -> ModelStore {
        ModelStore { schema_fp: schema.fingerprint(), extract, models: Vec::new() }
    }

    /// Add or replace (by device name) a stored model.
    pub fn insert(&mut self, sm: StoredModel) {
        match self.models.iter_mut().find(|m| m.device() == sm.device()) {
            Some(slot) => *slot = sm,
            None => self.models.push(sm),
        }
    }

    pub fn get(&self, device: &str) -> Option<&StoredModel> {
        self.models.iter().find(|m| m.device() == device)
    }

    pub fn devices(&self) -> Vec<String> {
        self.models.iter().map(|m| m.device().to_string()).collect()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// Digest of the whole store — format, schema fingerprint and every
    /// model's device + profile/suite fingerprints — surfaced by the
    /// `{"cmd": "health"}` response so operators can tell *which*
    /// artifact a server answers from (and see a hot reload land).
    pub fn fingerprint(&self) -> String {
        let mut h = Fnv64::new();
        h.write_str(FORMAT);
        h.write_str(&self.schema_fp);
        h.write_u64(self.models.len() as u64);
        for sm in &self.models {
            h.write_str(sm.device());
            h.write_str(&sm.profile_fp);
            h.write_str(&sm.suite_fp);
            h.write_f64(sm.launch_overhead_s);
        }
        h.hex()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Staleness validation: every stored model's device must exist in
    /// `registry` with an *identical* profile fingerprint, its suite
    /// fingerprint must match the suite that profile derives today, and
    /// the schema fingerprint must match `schema`. Errors name the
    /// first offending device and fingerprint kind.
    pub fn validate_against(
        &self,
        registry: &crate::gpusim::DeviceRegistry,
        schema: &Schema,
    ) -> Result<(), String> {
        if self.schema_fp != schema.fingerprint() {
            return Err(format!(
                "model artifact is stale: schema fingerprint {} does not match the \
                 current property schema {} — re-run `fit --save`",
                self.schema_fp,
                schema.fingerprint()
            ));
        }
        for sm in &self.models {
            let profile = registry.get(sm.device()).ok_or_else(|| {
                format!(
                    "model artifact references unknown device '{}' (not in the registry)",
                    sm.device()
                )
            })?;
            if sm.profile_fp != profile_fingerprint(profile) {
                return Err(format!(
                    "model artifact for '{}' is stale: device profile changed since the \
                     fit (fingerprint {} vs current {}) — re-run `fit --save`",
                    sm.device(),
                    sm.profile_fp,
                    profile_fingerprint(profile)
                ));
            }
            let current_suite = suite_fingerprint(profile);
            if sm.suite_fp != current_suite {
                return Err(format!(
                    "model artifact for '{}' is stale: measurement suite changed since \
                     the fit (fingerprint {} vs current {}) — re-run `fit --save`",
                    sm.device(),
                    sm.suite_fp,
                    current_suite
                ));
            }
        }
        Ok(())
    }

    /// The full serving gate ([`crate::engine::Engine::install_store`]):
    /// staleness validation plus the extraction-option match (serving
    /// with different options would evaluate property vectors the
    /// weights were never fitted against) and a non-emptiness check.
    pub fn validate_for_serving(
        &self,
        registry: &crate::gpusim::DeviceRegistry,
        schema: &Schema,
        extract: ExtractOpts,
    ) -> Result<(), String> {
        self.validate_against(registry, schema)?;
        if self.extract != extract {
            return Err(format!(
                "model artifact was fitted under extraction options {:?} but the \
                 service was configured with {:?} — serve with matching flags or \
                 re-run `fit --save`",
                self.extract, extract
            ));
        }
        if self.is_empty() {
            return Err("model artifact holds no fitted devices".into());
        }
        Ok(())
    }

    pub fn to_json(&self, schema: &Schema) -> Json {
        // exhaustive destructure: a future ExtractOpts field fails to
        // compile here instead of being silently dropped from the
        // artifact (and from the staleness gate that reads it back)
        let ExtractOpts { collapse_utilization, bin_local_strides } = self.extract;
        Json::obj(vec![
            ("format", Json::Str(FORMAT.into())),
            ("schema_fp", Json::Str(self.schema_fp.clone())),
            (
                "extract",
                Json::obj(vec![
                    ("collapse_utilization", Json::Bool(collapse_utilization)),
                    ("bin_local_strides", Json::Bool(bin_local_strides)),
                ]),
            ),
            (
                "models",
                Json::Arr(
                    self.models
                        .iter()
                        .map(|sm| {
                            Json::obj(vec![
                                ("device", Json::Str(sm.device().to_string())),
                                ("profile_fp", Json::Str(sm.profile_fp.clone())),
                                ("suite_fp", Json::Str(sm.suite_fp.clone())),
                                ("launch_overhead_s", Json::Num(sm.launch_overhead_s)),
                                (
                                    "n_measurement_cases",
                                    Json::Num(sm.n_measurement_cases as f64),
                                ),
                                ("model", sm.model.to_json(schema)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json, schema: &Schema) -> Result<ModelStore, String> {
        check_format(j, FORMAT, "model artifact")?;
        let schema_fp = j
            .get_str("schema_fp")
            .ok_or("model artifact: missing 'schema_fp'")?
            .to_string();
        let ej = j.get("extract").ok_or("model artifact: missing 'extract' options")?;
        let extract_flag = |key: &str| -> Result<bool, String> {
            ej.get(key)
                .and_then(Json::as_bool)
                .ok_or_else(|| format!("model artifact: missing boolean 'extract.{key}'"))
        };
        let extract = ExtractOpts {
            collapse_utilization: extract_flag("collapse_utilization")?,
            bin_local_strides: extract_flag("bin_local_strides")?,
        };
        let mut store = ModelStore { schema_fp, extract, models: Vec::new() };
        for entry in j
            .get("models")
            .and_then(Json::as_arr)
            .ok_or("model artifact: missing 'models' array")?
        {
            let device = entry
                .get_str("device")
                .ok_or("model artifact entry: missing 'device'")?;
            let model = Model::from_json(
                entry.get("model").ok_or("model artifact entry: missing 'model'")?,
                schema,
            )?;
            if model.device != device {
                return Err(format!(
                    "model artifact entry for '{device}' wraps a model fitted for '{}'",
                    model.device
                ));
            }
            store.insert(StoredModel {
                model,
                launch_overhead_s: entry
                    .get_f64("launch_overhead_s")
                    .ok_or("model artifact entry: missing 'launch_overhead_s'")?,
                n_measurement_cases: entry
                    .get_i64("n_measurement_cases")
                    .filter(|n| *n >= 0)
                    .ok_or(
                        "model artifact entry: 'n_measurement_cases' must be a \
                         non-negative integer",
                    )? as usize,
                profile_fp: entry
                    .get_str("profile_fp")
                    .ok_or("model artifact entry: missing 'profile_fp'")?
                    .to_string(),
                suite_fp: entry
                    .get_str("suite_fp")
                    .ok_or("model artifact entry: missing 'suite_fp'")?
                    .to_string(),
            });
        }
        Ok(store)
    }

    /// Write the artifact to disk (pretty JSON, diff-friendly).
    pub fn save(&self, path: &Path, schema: &Schema) -> Result<(), String> {
        std::fs::write(path, self.to_json(schema).pretty())
            .map_err(|e| format!("write {}: {e}", path.display()))
    }

    /// Load an artifact from disk (no staleness validation yet; call
    /// [`ModelStore::validate_against`] before serving from it).
    pub fn load(path: &Path, schema: &Schema) -> Result<ModelStore, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let doc =
            Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
        ModelStore::from_json(&doc, schema)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::gpusim::registry::builtins;

    fn toy_model(device: &str, schema: &Schema) -> Model {
        let mut weights = vec![0.0; schema.len()];
        weights[0] = 1.5e-9;
        weights[schema.len() - 1] = 2.0e-6;
        Model {
            device: device.into(),
            weights,
            active: vec![0, schema.len() - 1],
            train_rel_err_geomean: 0.12,
            solver: "native-cholesky",
        }
    }

    #[test]
    fn store_roundtrip_preserves_predictions_bit_exactly() {
        let schema = Schema::full();
        let profile = builtins().get("k40c").unwrap();
        let mut store = ModelStore::new(&schema, ExtractOpts::default());
        store.insert(StoredModel::new(toy_model("k40c", &schema), 8e-6, 400, profile));
        let text = store.to_json(&schema).pretty();
        let back = ModelStore::from_json(&Json::parse(&text).unwrap(), &schema).unwrap();
        assert_eq!(back.devices(), vec!["k40c".to_string()]);
        let (a, b) = (store.get("k40c").unwrap(), back.get("k40c").unwrap());
        assert_eq!(a.model.weights, b.model.weights);
        assert_eq!(a.profile_fp, b.profile_fp);
        assert_eq!(a.suite_fp, b.suite_fp);
        // serialization is a fixed point: re-emitting the loaded store
        // reproduces the artifact byte for byte
        assert_eq!(text, back.to_json(&schema).pretty());
        back.validate_against(builtins(), &schema).unwrap();
    }

    #[test]
    fn stale_profile_and_suite_are_rejected() {
        let schema = Schema::full();
        let profile = builtins().get("k40c").unwrap();
        let mut store = ModelStore::new(&schema, ExtractOpts::default());
        store.insert(StoredModel::new(toy_model("k40c", &schema), 8e-6, 400, profile));

        // tampered profile fingerprint
        let mut bad = store.clone();
        bad.models[0].profile_fp = "0000000000000000".into();
        let e = bad.validate_against(builtins(), &schema).unwrap_err();
        assert!(e.contains("profile changed"), "{e}");

        // tampered suite fingerprint
        let mut bad = store.clone();
        bad.models[0].suite_fp = "0000000000000000".into();
        let e = bad.validate_against(builtins(), &schema).unwrap_err();
        assert!(e.contains("suite changed"), "{e}");

        // unknown device
        let mut bad = store.clone();
        bad.models[0].model.device = "gtx480".into();
        let e = bad.validate_against(builtins(), &schema).unwrap_err();
        assert!(e.contains("unknown device"), "{e}");

        // schema drift
        let mut bad = store;
        bad.schema_fp = "0000000000000000".into();
        let e = bad.validate_against(builtins(), &schema).unwrap_err();
        assert!(e.contains("schema fingerprint"), "{e}");
    }

    #[test]
    fn unknown_artifact_formats_are_rejected_at_load() {
        let schema = Schema::full();
        let profile = builtins().get("k40c").unwrap();
        let mut store = ModelStore::new(&schema, ExtractOpts::default());
        store.insert(StoredModel::new(toy_model("k40c", &schema), 8e-6, 400, profile));
        let good = store.to_json(&schema).pretty();
        // a v2 artifact fails with a format message, not a fingerprint one
        let v2 = good.replace("uniperf-models-v1", "uniperf-models-v2");
        let e = ModelStore::from_json(&Json::parse(&v2).unwrap(), &schema).unwrap_err();
        assert!(e.contains("uniperf-models-v2") && e.contains("format"), "{e}");
        // and a tagless blob is refused too
        let tagless = good.replace("\"format\": \"uniperf-models-v1\",", "");
        let e = ModelStore::from_json(&Json::parse(&tagless).unwrap(), &schema).unwrap_err();
        assert!(e.contains("format"), "{e}");
    }

    #[test]
    fn fingerprints_react_to_profile_edits() {
        let p = builtins().get("titan_x").unwrap().clone();
        let base_p = profile_fingerprint(&p);
        let base_s = suite_fingerprint(&p);
        let mut edited = p.clone();
        edited.dram_bw *= 1.01;
        assert_ne!(base_p, profile_fingerprint(&edited));
        // the suite is capability-derived: a group-cap change reshapes it
        let mut capped = p;
        capped.max_group_size = 256;
        assert_ne!(base_s, suite_fingerprint(&capped));
    }

    #[test]
    fn store_fingerprint_tracks_content() {
        let schema = Schema::full();
        let profile = builtins().get("k40c").unwrap();
        let mut store = ModelStore::new(&schema, ExtractOpts::default());
        store.insert(StoredModel::new(toy_model("k40c", &schema), 8e-6, 400, profile));
        let base = store.fingerprint();
        // deterministic across roundtrips
        let text = store.to_json(&schema).pretty();
        let back = ModelStore::from_json(&Json::parse(&text).unwrap(), &schema).unwrap();
        assert_eq!(base, back.fingerprint());
        // any content change moves it
        let mut more = store.clone();
        more.insert(StoredModel::new(
            toy_model("titan_x", &schema),
            7e-6,
            400,
            builtins().get("titan_x").unwrap(),
        ));
        assert_ne!(base, more.fingerprint());
        let mut retimed = store;
        retimed.models[0].launch_overhead_s = 9e-6;
        assert_ne!(base, retimed.fingerprint());
    }

    #[test]
    fn insert_replaces_by_device() {
        let schema = Schema::full();
        let profile = builtins().get("k40c").unwrap();
        let mut store = ModelStore::new(&schema, ExtractOpts::default());
        store.insert(StoredModel::new(toy_model("k40c", &schema), 8e-6, 400, profile));
        let mut m2 = toy_model("k40c", &schema);
        m2.weights[0] = 9e-9;
        store.insert(StoredModel::new(m2, 9e-6, 410, profile));
        assert_eq!(store.len(), 1);
        assert_eq!(store.get("k40c").unwrap().model.weights[0], 9e-9);
    }
}
