//! `reactor` — the event-driven TCP transport: one epoll readiness
//! loop, nonblocking sockets, and a fixed worker pool pulling *formed
//! batches* instead of connections.
//!
//! The threaded transport ([`super::tcp`]) spends one OS thread per
//! connection and a 250 ms read-timeout poll per idle socket: a
//! thousand mostly-idle keep-alive clients cost a thousand parked
//! threads and four thousand wakeups a second, and the SoA tape
//! evaluator's batch speedup is only realized when one client happens
//! to pipeline. Here a single reactor thread owns *every* socket
//! through one `epoll` instance (raw syscalls, no `libc` — this crate
//! has no dependencies), frames request lines off nonblocking reads,
//! and coalesces lines from *many* connections into one
//! [`Service::respond_batch`] call dispatched to a fixed
//! [`WorkerPool`](crate::util::executor::WorkerPool):
//!
//! * **Batch formation** — pending request lines are dispatched when
//!   the batch size cap is reached *or* the oldest line has waited
//!   [`ReactorConfig::batch_ms`] (so a lone conversational client pays
//!   at most the window in latency, and concurrent narrow clients get
//!   coalesced into wide `Engine::predict_batch` calls).
//! * **Ordering** — responses are routed back per-connection in
//!   arrival order (a sequence number per line, a reorder buffer per
//!   connection), so each client observes exactly the conversational
//!   contract the threaded loop provides.
//! * **Backpressure** — a bounded global formation queue
//!   ([`super::ServiceConfig::queue_cap`], counting in-flight batches)
//!   and a per-connection write-buffer cap
//!   ([`ReactorConfig::write_buf_cap`]) shed with
//!   `"reason": "overloaded"` instead of growing memory; `EMFILE`/
//!   `ENFILE` on accept drops a reserve fd to drain one pending
//!   connection, then disarms accept for a backoff window instead of
//!   spinning hot on the error.
//! * **Drain** — `{"cmd": "shutdown"}` stops accepting and reading,
//!   flushes every response already owed, joins the worker pool, and
//!   returns the summary — the same deterministic contract as
//!   [`super::tcp::serve_threaded`].
//! * **Faults** — the `conn.abort` / `conn.slow` chaos sites behave
//!   exactly as in the threaded transport: abort drops an accepted
//!   connection before a byte is served; slow defers the connection's
//!   first read by the same delay the threaded loop sleeps.
//!
//! The raw-epoll core is Linux (x86_64/aarch64) tier-1;
//! [`supported`] reports availability at runtime and `main.rs` falls
//! back to the threaded transport elsewhere.

/// Default cross-connection batch-formation window (milliseconds): how
/// long the oldest pending request line may wait before its batch is
/// dispatched regardless of width.
pub const DEFAULT_BATCH_MS: f64 = 2.0;

/// Default per-connection write-buffer cap (bytes): responses owed to
/// a client that never reads are bounded; further request lines from
/// that connection shed with `"reason": "overloaded"`.
pub const DEFAULT_WRITE_BUF_CAP: usize = 256 * 1024;

/// Event-driven transport configuration (the service-level knobs —
/// queue bound, line cap, extraction — live in
/// [`super::ServiceConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct ReactorConfig {
    /// concurrent-connection guard (same contract as the threaded
    /// transport's cap: above it a connection is answered with one
    /// overload error line and closed)
    pub max_conns: usize,
    /// batch-formation latency window, milliseconds
    pub batch_ms: f64,
    /// batch-formation size cap (requests per formed batch)
    pub batch_cap: usize,
    /// fixed worker-pool size (defaults to one per core)
    pub workers: usize,
    /// per-connection write-buffer cap, bytes
    pub write_buf_cap: usize,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            max_conns: super::tcp::DEFAULT_MAX_CONNECTIONS,
            batch_ms: DEFAULT_BATCH_MS,
            batch_cap: 64,
            workers: crate::util::executor::default_workers(),
            write_buf_cap: DEFAULT_WRITE_BUF_CAP,
        }
    }
}

/// Is the epoll reactor available on this target?
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn supported() -> bool {
    true
}

/// Is the epoll reactor available on this target?
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn supported() -> bool {
    false
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub use imp::serve_reactor;

/// Portable stub: the raw-epoll reactor needs Linux syscall numbers;
/// other targets keep the threaded transport.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn serve_reactor(
    _svc: &std::sync::Arc<super::Service>,
    _listener: std::net::TcpListener,
    _cfg: ReactorConfig,
) -> Result<crate::report::ServiceSummary, String> {
    Err("the epoll reactor transport requires Linux on x86_64/aarch64; \
         run with --transport threaded"
        .into())
}

/// Thin, `libc`-free epoll bindings: the four syscalls the reactor
/// needs (`epoll_create1`, `epoll_ctl`, `epoll_pwait`, `close`) issued
/// through inline assembly. Everything else — sockets, accept, the
/// worker wake channel — goes through `std`, so this is the entire
/// unsafe surface of the transport.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    /// readable (or a peer closed its write half)
    pub const EPOLLIN: u32 = 0x1;
    /// writable
    pub const EPOLLOUT: u32 = 0x4;
    /// error condition (always reported; treated as readable so the
    /// read path observes and classifies the failure)
    pub const EPOLLERR: u32 = 0x8;
    /// hangup (always reported; treated as readable so the read path
    /// observes EOF)
    pub const EPOLLHUP: u32 = 0x10;

    const EPOLL_CTL_ADD: usize = 1;
    const EPOLL_CTL_DEL: usize = 2;
    const EPOLL_CTL_MOD: usize = 3;
    const EPOLL_CLOEXEC: usize = 0x8_0000;
    const EINTR: isize = 4;

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const CLOSE: usize = 3;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_PWAIT: usize = 281;
        pub const EPOLL_CREATE1: usize = 291;
    }

    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const EPOLL_CREATE1: usize = 20;
        pub const EPOLL_CTL: usize = 21;
        pub const EPOLL_PWAIT: usize = 22;
        pub const CLOSE: usize = 57;
    }

    /// One readiness record. x86_64's kernel ABI packs this struct
    /// (12 bytes); every other architecture pads it to 16. Fields are
    /// only ever accessed by value-copy, which is safe on a packed
    /// struct.
    #[derive(Clone, Copy)]
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    pub struct EpollEvent {
        events: u32,
        data: u64,
    }

    impl EpollEvent {
        pub fn zeroed() -> EpollEvent {
            EpollEvent { events: 0, data: 0 }
        }

        pub fn events(self) -> u32 {
            self.events
        }

        pub fn data(self) -> u64 {
            self.data
        }
    }

    /// Issue one raw 5-argument syscall; returns the kernel's raw
    /// result (negative values in `[-4095, -1]` are `-errno`).
    fn syscall(nr: usize, a1: usize, a2: usize, a3: usize, a4: usize, a5: usize) -> isize {
        let ret: isize;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the syscall instruction with the Linux x86_64 calling
        // convention (number in rax, args in rdi/rsi/rdx/r10/r8; the
        // kernel clobbers rcx and r11, declared below). All pointers
        // passed by callers in this module reference live memory for
        // the duration of the call.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") nr as isize => ret,
                in("rdi") a1,
                in("rsi") a2,
                in("rdx") a3,
                in("r10") a4,
                in("r8") a5,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `svc #0` with the Linux aarch64 calling convention
        // (number in x8, args in x0..x4, result in x0; no other
        // registers clobbered). All pointers passed by callers in this
        // module reference live memory for the duration of the call.
        unsafe {
            std::arch::asm!(
                "svc #0",
                in("x8") nr,
                inlateout("x0") a1 => ret,
                in("x1") a2,
                in("x2") a3,
                in("x3") a4,
                in("x4") a5,
                options(nostack),
            );
        }
        ret
    }

    fn check(ret: isize, what: &str) -> Result<usize, String> {
        if ret < 0 {
            Err(format!("{what} failed (errno {})", -ret))
        } else {
            Ok(ret as usize)
        }
    }

    /// An owned epoll instance (closed on drop).
    pub struct Epoll {
        fd: i32,
    }

    impl Epoll {
        pub fn new() -> Result<Epoll, String> {
            let fd = check(
                syscall(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0),
                "epoll_create1",
            )?;
            Ok(Epoll { fd: fd as i32 })
        }

        fn ctl(&self, op: usize, fd: i32, events: u32, data: u64) -> Result<(), String> {
            let ev = EpollEvent { events, data };
            check(
                syscall(
                    nr::EPOLL_CTL,
                    self.fd as usize,
                    op,
                    fd as usize,
                    // DEL ignores the event on any kernel this runs on,
                    // but passing a live pointer is valid everywhere
                    &ev as *const EpollEvent as usize,
                    0,
                ),
                "epoll_ctl",
            )
            .map(|_| ())
        }

        pub fn add(&self, fd: i32, events: u32, data: u64) -> Result<(), String> {
            self.ctl(EPOLL_CTL_ADD, fd, events, data)
        }

        pub fn modify(&self, fd: i32, events: u32, data: u64) -> Result<(), String> {
            self.ctl(EPOLL_CTL_MOD, fd, events, data)
        }

        pub fn del(&self, fd: i32) -> Result<(), String> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Wait for readiness; `timeout_ms < 0` blocks indefinitely.
        /// Interrupted waits retry. Returns how many events were
        /// written into `buf`.
        pub fn wait(&self, buf: &mut [EpollEvent], timeout_ms: i32) -> Result<usize, String> {
            loop {
                let ret = syscall(
                    nr::EPOLL_PWAIT,
                    self.fd as usize,
                    buf.as_mut_ptr() as usize,
                    buf.len(),
                    timeout_ms as isize as usize,
                    // NULL sigmask: plain epoll_wait semantics (the
                    // kernel never reads sigsetsize when the mask is
                    // NULL, so the 5-argument form suffices)
                    0,
                );
                if ret == -EINTR {
                    continue;
                }
                return check(ret, "epoll_wait");
            }
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            let _ = syscall(nr::CLOSE, self.fd as usize, 0, 0, 0, 0);
        }
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use super::super::{locked, Service};
    use super::sys::{Epoll, EpollEvent, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT};
    use super::ReactorConfig;
    use crate::obs::log::Level;
    use crate::obs::span::{self, Span};
    use crate::olog;
    use crate::report::ServiceSummary;
    use crate::util::executor::WorkerPool;
    use crate::util::json::Json;
    use std::collections::{BTreeMap, VecDeque};
    use std::io::{ErrorKind, Read, Write};
    use std::net::{Shutdown, TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    /// How long the shutdown drain waits for in-flight batches and
    /// unflushed responses before giving up (a hostile client that
    /// never reads must not pin the listener forever).
    const DRAIN_GRACE: Duration = Duration::from_secs(5);

    /// How long accept stays disarmed after fd exhaustion.
    const ACCEPT_BACKOFF: Duration = Duration::from_millis(100);

    /// Readiness events drained per `epoll_wait`, and the accept-loop
    /// bound per listener event (level-triggered epoll re-arms, so
    /// bounding both only buys fairness, never loses wakeups).
    const MAX_EVENTS: usize = 256;

    /// Per-`read` chunk size, and the bound on how far one connection
    /// may over-read past the line cap before the framer resyncs.
    const READ_CHUNK: usize = 16 * 1024;

    /// Socket-read rounds per readiness event: fairness across
    /// connections (a firehose client yields after this many chunks;
    /// level-triggering re-reports it immediately).
    const READ_ROUNDS: usize = 16;

    /// Keep at most this much already-written prefix in a connection's
    /// write buffer before compacting it.
    const WRITE_COMPACT: usize = 64 * 1024;

    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKE: u64 = 1;

    /// Epoll token for a connection slot: the slot index (offset past
    /// the two fixed tokens) plus a generation stamp so a stale kernel
    /// event for a closed connection can never alias its slot's next
    /// tenant.
    fn token_for(slot: usize, gen: u32) -> u64 {
        ((slot as u64) + 2) | ((gen as u64) << 32)
    }

    /// One request line waiting in (or dispatched from) the global
    /// formation queue.
    struct Item {
        slot: usize,
        gen: u32,
        /// per-connection arrival index (routes the response back in
        /// conversational order)
        seq: u64,
        line: String,
        /// when the line was framed off the socket (`deadline_ms`
        /// budgets are measured from here, so formation-window wait
        /// counts against them — same rule as the batched stdin loop)
        at: Instant,
    }

    /// One rendered response traveling back from a pool worker.
    struct Done {
        slot: usize,
        gen: u32,
        seq: u64,
        /// compact JSON + trailing newline, ready for the socket
        text: String,
    }

    /// Worker→reactor completion channel: a locked vector plus a
    /// nonblocking socketpair byte to wake the epoll wait. Completions
    /// are pushed *before* the wake byte is written, and a full wake
    /// buffer means the reactor is already waking — no completion can
    /// be stranded.
    struct Shared {
        done: Mutex<Vec<Done>>,
        wake_tx: UnixStream,
    }

    impl Shared {
        fn notify(&self) {
            let _ = (&self.wake_tx).write(&[1u8]);
        }
    }

    /// One framed unit off a connection's read buffer.
    enum LineEvent {
        Line(String),
        /// line blew the cap; the retained prefix salvages the id
        Oversized(Vec<u8>),
        BadUtf8,
    }

    /// One nonblocking connection's state.
    struct Conn {
        stream: TcpStream,
        gen: u32,
        /// unframed bytes read off the socket
        rbuf: Vec<u8>,
        /// prefix of `rbuf` already scanned for a newline
        scanned: usize,
        /// inside the tail of an oversized line, dropping to the next
        /// newline
        discarding: bool,
        /// rendered responses being written (prefix `wpos` already
        /// sent)
        wbuf: Vec<u8>,
        wpos: usize,
        /// next arrival index to assign to a framed line
        next_seq: u64,
        /// next arrival index owed to the socket
        next_write: u64,
        /// completed responses waiting for their turn (keyed by seq)
        done: BTreeMap<u64, String>,
        /// bytes held in `done` (counted toward the write-buffer cap)
        done_bytes: usize,
        /// lines owned by the pipeline (formation queue + workers)
        awaiting: usize,
        eof: bool,
        dead: bool,
        /// `conn.slow` fault: no reads before this instant
        defer_until: Option<Instant>,
        /// interest bits currently registered with epoll
        interest: u32,
    }

    impl Conn {
        /// Bytes owed to this client but not yet on the wire.
        fn backlog(&self) -> usize {
            (self.wbuf.len() - self.wpos) + self.done_bytes
        }
    }

    /// Serve `listener` with the epoll reactor until a shutdown request
    /// drains it. Returns the service summary once every in-flight
    /// batch has been handled and the worker pool joined.
    pub fn serve_reactor(
        svc: &Arc<Service>,
        listener: TcpListener,
        cfg: ReactorConfig,
    ) -> Result<ServiceSummary, String> {
        let mut reactor = Reactor::new(svc, listener, cfg)?;
        reactor.run();
        if let Some(pool) = reactor.pool.take() {
            pool.join();
        }
        Ok(reactor.svc.summary())
    }

    struct Reactor {
        svc: Arc<Service>,
        cfg: ReactorConfig,
        epoll: Epoll,
        listener: TcpListener,
        /// is the listener currently registered for EPOLLIN?
        listener_armed: bool,
        /// fd-exhaustion backoff: accept re-arms at this instant
        accept_resume: Option<Instant>,
        /// reserve fd dropped on EMFILE/ENFILE so one pending
        /// connection can be accepted and shed instead of sitting in
        /// the backlog retrying forever
        reserve: Option<std::fs::File>,
        wake_rx: UnixStream,
        shared: Arc<Shared>,
        pool: Option<WorkerPool<Vec<Item>>>,
        /// connection slab (slot indices are epoll tokens)
        conns: Vec<Option<Conn>>,
        free: Vec<usize>,
        n_conns: usize,
        /// generation stamp for slot reuse
        gen: u32,
        /// global cross-connection formation queue
        pending: VecDeque<Item>,
        /// lines dispatched to the pool, not yet completed
        inflight: usize,
        draining: bool,
        drain_deadline: Option<Instant>,
    }

    impl Reactor {
        fn new(
            svc: &Arc<Service>,
            listener: TcpListener,
            cfg: ReactorConfig,
        ) -> Result<Reactor, String> {
            listener
                .set_nonblocking(true)
                .map_err(|e| format!("listener nonblocking: {e}"))?;
            let epoll = Epoll::new()?;
            let (wake_tx, wake_rx) =
                UnixStream::pair().map_err(|e| format!("wake channel: {e}"))?;
            wake_tx
                .set_nonblocking(true)
                .map_err(|e| format!("wake channel: {e}"))?;
            wake_rx
                .set_nonblocking(true)
                .map_err(|e| format!("wake channel: {e}"))?;
            epoll.add(listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
            epoll.add(wake_rx.as_raw_fd(), EPOLLIN, TOKEN_WAKE)?;
            let shared = Arc::new(Shared { done: Mutex::new(Vec::new()), wake_tx });
            let pool = {
                let svc = Arc::clone(svc);
                let shared = Arc::clone(&shared);
                // workers get whole formed batches; parallelism is
                // across batches, so the engine call inside runs
                // single-worker
                WorkerPool::new(cfg.workers.max(1), move |batch: Vec<Item>| {
                    let keys: Vec<(usize, u32, u64)> =
                        batch.iter().map(|i| (i.slot, i.gen, i.seq)).collect();
                    let lines: Vec<(String, Instant)> =
                        batch.into_iter().map(|i| (i.line, i.at)).collect();
                    // the `svc.batch` span inside respond_batch nests
                    // under this dispatch span (same worker thread)
                    let mut sp = Span::root("reactor.dispatch");
                    if span::enabled() {
                        sp.set_meta(format!("width={}", lines.len()));
                    }
                    let responses = svc.respond_batch(lines, 1);
                    drop(sp);
                    let mut done = locked(&shared.done);
                    for ((slot, gen, seq), resp) in keys.into_iter().zip(responses) {
                        done.push(Done {
                            slot,
                            gen,
                            seq,
                            text: format!("{}\n", resp.compact()),
                        });
                    }
                    drop(done);
                    shared.notify();
                })
            };
            Ok(Reactor {
                svc: Arc::clone(svc),
                cfg,
                epoll,
                listener,
                listener_armed: true,
                accept_resume: None,
                reserve: std::fs::File::open("/dev/null").ok(),
                wake_rx,
                shared,
                pool: Some(pool),
                conns: Vec::new(),
                free: Vec::new(),
                n_conns: 0,
                gen: 0,
                pending: VecDeque::new(),
                inflight: 0,
                draining: false,
                drain_deadline: None,
            })
        }

        fn run(&mut self) {
            let mut events = [EpollEvent::zeroed(); MAX_EVENTS];
            loop {
                self.form_batches();
                self.svc.note_queue_depth(self.pending.len());
                if self.svc.shutdown_requested() && !self.draining {
                    self.begin_drain();
                }
                if self.drain_finished() {
                    break;
                }
                let timeout = self.timeout_ms();
                let n = match self.epoll.wait(&mut events, timeout) {
                    Ok(n) => n,
                    Err(e) => {
                        olog!(Level::Error, "uniperf serve: reactor wait failed: {e}");
                        break;
                    }
                };
                for ev in events.iter().take(n) {
                    match ev.data() {
                        TOKEN_LISTENER => self.accept_ready(),
                        TOKEN_WAKE => self.drain_wake(),
                        token => self.conn_event(token, ev.events()),
                    }
                }
                self.apply_completions();
                self.resume_timers();
            }
            // responses completed during the final wait still land
            self.apply_completions();
        }

        /// Dispatch formed batches: size cap reached, the oldest line's
        /// window expired, or draining (drain answers everything read).
        fn form_batches(&mut self) {
            let window = Duration::from_secs_f64(self.cfg.batch_ms.max(0.0) / 1e3);
            let cap = self.cfg.batch_cap.max(1);
            loop {
                let n = self.pending.len();
                if n == 0 {
                    return;
                }
                let window_due = match self.pending.front() {
                    Some(i) => i.at.elapsed() >= window,
                    None => false,
                };
                if n < cap && !window_due && !self.draining {
                    return;
                }
                let take = n.min(cap);
                let mut sp = Span::root("reactor.formation");
                if span::enabled() {
                    sp.set_meta(format!("width={take}"));
                }
                let batch: Vec<Item> = self.pending.drain(..take).collect();
                self.inflight += batch.len();
                // hot reload between dispatched batches — the same
                // cadence the threaded loop polls at
                self.svc.reload_tick();
                if let Some(pool) = &self.pool {
                    pool.submit(batch);
                }
                drop(sp);
            }
        }

        /// Next wait timeout: the earliest of the formation window, the
        /// accept-backoff resume, any `conn.slow` defer, and a 50 ms
        /// drain poll; `-1` (block) when nothing is timed.
        fn timeout_ms(&self) -> i32 {
            fn min_opt(next: &mut Option<Instant>, cand: Instant) {
                let better = match *next {
                    Some(n) => cand < n,
                    None => true,
                };
                if better {
                    *next = Some(cand);
                }
            }
            let mut next: Option<Instant> = None;
            if let Some(item) = self.pending.front() {
                let window = Duration::from_secs_f64(self.cfg.batch_ms.max(0.0) / 1e3);
                min_opt(&mut next, item.at + window);
            }
            if let Some(t) = self.accept_resume {
                min_opt(&mut next, t);
            }
            for conn in self.conns.iter().flatten() {
                if let Some(t) = conn.defer_until {
                    min_opt(&mut next, t);
                }
            }
            if self.draining {
                min_opt(&mut next, Instant::now() + Duration::from_millis(50));
            }
            match next {
                None => -1,
                Some(next) => {
                    let d = next.saturating_duration_since(Instant::now());
                    let ms = (d.as_nanos() + 999_999) / 1_000_000;
                    ms.min(i32::MAX as u128) as i32
                }
            }
        }

        /// Drain the wake channel (the byte is a doorbell, not data).
        fn drain_wake(&mut self) {
            let mut buf = [0u8; 64];
            loop {
                match (&self.wake_rx).read(&mut buf) {
                    Ok(0) => break,
                    Ok(_) => continue,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }

        fn accept_ready(&mut self) {
            if self.draining || !self.listener_armed || self.accept_resume.is_some() {
                return;
            }
            for _ in 0..MAX_EVENTS {
                let stream = match self.listener.accept() {
                    Ok((s, _)) => s,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => {
                        let fd_exhausted = matches!(e.raw_os_error(), Some(23) | Some(24));
                        if let Some(msg) = self.svc.note_accept_error(&e) {
                            olog!(Level::Warn, "uniperf serve: {msg}");
                        }
                        if fd_exhausted {
                            // EMFILE/ENFILE: drop the reserve fd so one
                            // backlogged connection can be accepted and
                            // shed (instead of the client hanging in the
                            // SYN queue), then disarm accept for a
                            // backoff window instead of spinning hot
                            self.reserve = None;
                            if let Ok((s, _)) = self.listener.accept() {
                                drop(s);
                            }
                            self.reserve = std::fs::File::open("/dev/null").ok();
                            self.svc.note_accept_backoff();
                            self.accept_resume = Some(Instant::now() + ACCEPT_BACKOFF);
                            self.set_listener_interest(false);
                        }
                        break;
                    }
                };
                self.install(stream);
                if self.svc.shutdown_requested() {
                    break;
                }
            }
        }

        /// Register one accepted connection: fault sites, reload poll
        /// and the connection-count guard first (identical order to the
        /// threaded accept path), then the slab slot and epoll
        /// registration.
        fn install(&mut self, stream: TcpStream) {
            // chaos: conn.abort drops the connection before a byte is
            // served — clients observe a reset, accounting is untouched
            if let Some(plan) = self.svc.fault_plan() {
                if plan.should_inject("conn.abort") {
                    self.svc.note_conn_aborted();
                    return;
                }
            }
            if let Some(Err(e)) = self.svc.poll_reload() {
                olog!(
                    Level::Warn,
                    "uniperf serve: artifact reload failed (keeping current models): {e}"
                );
            }
            let cap = self.cfg.max_conns.max(1);
            if self.n_conns >= cap {
                // guard: one overload line, blockingly (accepted
                // sockets do not inherit the listener's nonblocking
                // flag), then close
                let mut s = stream;
                let resp = self.svc.conn_guard_response(cap);
                let _ = writeln!(s, "{}", resp.compact());
                return;
            }
            // chaos: conn.slow defers this connection's first read by
            // the same delay the threaded transport sleeps
            let mut defer_until = None;
            if let Some(plan) = self.svc.fault_plan() {
                if plan.should_inject("conn.slow") {
                    self.svc.note_conn_slowed();
                    defer_until = Some(Instant::now() + super::super::tcp::SLOW_CONN_DELAY);
                }
            }
            let _ = stream.set_nodelay(true);
            if let Err(e) = stream.set_nonblocking(true) {
                olog!(Level::Warn, "uniperf serve: connection setup failed: {e}");
                return;
            }
            self.gen = self.gen.wrapping_add(1);
            let gen = self.gen;
            let slot = match self.free.pop() {
                Some(s) => s,
                None => {
                    self.conns.push(None);
                    self.conns.len() - 1
                }
            };
            let interest = if defer_until.is_some() { 0 } else { EPOLLIN };
            if let Err(e) = self.epoll.add(stream.as_raw_fd(), interest, token_for(slot, gen)) {
                olog!(Level::Warn, "uniperf serve: connection registration failed: {e}");
                self.free.push(slot);
                return;
            }
            self.conns[slot] = Some(Conn {
                stream,
                gen,
                rbuf: Vec::new(),
                scanned: 0,
                discarding: false,
                wbuf: Vec::new(),
                wpos: 0,
                next_seq: 0,
                next_write: 0,
                done: BTreeMap::new(),
                done_bytes: 0,
                awaiting: 0,
                eof: false,
                dead: false,
                defer_until,
                interest,
            });
            self.n_conns += 1;
        }

        fn conn_event(&mut self, token: u64, bits: u32) {
            let slot = match (token & 0xFFFF_FFFF) as usize {
                s if s >= 2 => s - 2,
                _ => return,
            };
            let gen = (token >> 32) as u32;
            let live = match self.conns.get(slot) {
                Some(Some(c)) => c.gen == gen && !c.dead,
                _ => false,
            };
            if !live {
                // stale event for a closed generation
                return;
            }
            if bits & (EPOLLIN | EPOLLHUP | EPOLLERR) != 0 {
                self.do_read(slot);
            } else if bits & EPOLLOUT != 0 {
                self.pump(slot);
            }
        }

        fn do_read(&mut self, slot: usize) {
            let max_line = self.svc.config().max_line;
            let read = match self.conns.get_mut(slot).and_then(Option::as_mut) {
                Some(conn) if !conn.dead && conn.defer_until.is_none() => {
                    Some(drain_socket(conn, max_line))
                }
                _ => None,
            };
            let (events, hard_error) = match read {
                Some(r) => r,
                None => return,
            };
            for ev in events {
                match ev {
                    LineEvent::Line(line) => self.enqueue_line(slot, line),
                    LineEvent::Oversized(prefix) => {
                        // counted + rendered by the service (stream
                        // resyncs at the next newline, same as the
                        // buffered framer)
                        let resp = self.svc.oversized_line(&prefix);
                        self.complete_local(slot, resp);
                    }
                    LineEvent::BadUtf8 => {
                        // the buffered framer treats this as a
                        // connection-fatal stream error; match it
                        olog!(
                            Level::Warn,
                            "uniperf serve: connection error: read request stream: \
                             request line is not valid UTF-8"
                        );
                        self.kill_conn(slot);
                        return;
                    }
                }
            }
            if let Some(e) = hard_error {
                olog!(Level::Warn, "uniperf serve: connection error: read request stream: {e}");
                self.kill_conn(slot);
                return;
            }
            self.pump(slot);
        }

        /// Queue one framed request line into the global formation
        /// queue, or shed it when the queue (counting in-flight lines)
        /// or this connection's write backlog is at cap.
        fn enqueue_line(&mut self, slot: usize, line: String) {
            if line.trim().is_empty() {
                return;
            }
            let mut sp = Span::root("reactor.enqueue");
            let queue_cap = self.svc.config().queue_cap.max(1);
            let write_cap = self.cfg.write_buf_cap.max(1);
            let over_write = match self.conns.get(slot).and_then(Option::as_ref) {
                Some(c) => c.backlog() >= write_cap,
                None => return,
            };
            if over_write || self.pending.len() + self.inflight >= queue_cap {
                sp.set_meta("shed");
                let resp = self.svc.shed_line(&line);
                self.complete_local(slot, resp);
                return;
            }
            let (gen, seq) = match self.conns.get_mut(slot).and_then(Option::as_mut) {
                Some(c) => {
                    let seq = c.next_seq;
                    c.next_seq += 1;
                    c.awaiting += 1;
                    (c.gen, seq)
                }
                None => return,
            };
            sp.set_meta("queued");
            self.pending.push_back(Item { slot, gen, seq, line, at: Instant::now() });
        }

        /// Complete one response that never went through the pool
        /// (shed, oversized): it still consumes a sequence number so
        /// the per-connection stream order is preserved.
        fn complete_local(&mut self, slot: usize, resp: Json) {
            if let Some(c) = self.conns.get_mut(slot).and_then(Option::as_mut) {
                let seq = c.next_seq;
                c.next_seq += 1;
                let text = format!("{}\n", resp.compact());
                c.done_bytes += text.len();
                c.done.insert(seq, text);
            }
            self.pump(slot);
        }

        /// Route completed pool responses back to their connections.
        fn apply_completions(&mut self) {
            let done = std::mem::take(&mut *locked(&self.shared.done));
            if done.is_empty() {
                return;
            }
            let mut touched: Vec<usize> = Vec::new();
            for d in done {
                self.inflight = self.inflight.saturating_sub(1);
                let freed = match self.conns.get_mut(d.slot).and_then(Option::as_mut) {
                    Some(c) if c.gen == d.gen => {
                        c.awaiting = c.awaiting.saturating_sub(1);
                        if c.dead {
                            // the client is gone; the slot was only
                            // held so its in-flight work could land
                            c.awaiting == 0
                        } else {
                            c.done_bytes += d.text.len();
                            c.done.insert(d.seq, d.text);
                            if !touched.contains(&d.slot) {
                                touched.push(d.slot);
                            }
                            false
                        }
                    }
                    _ => false,
                };
                if freed {
                    self.free_slot(d.slot);
                }
            }
            for slot in touched {
                self.pump(slot);
            }
        }

        /// Move in-order completed responses into the write buffer,
        /// flush what the socket accepts, close when everything owed
        /// has been written to an EOF'd connection.
        fn pump(&mut self, slot: usize) {
            let mut kill = false;
            {
                let conn = match self.conns.get_mut(slot).and_then(Option::as_mut) {
                    Some(c) => c,
                    None => return,
                };
                if conn.dead {
                    return;
                }
                while let Some(text) = conn.done.remove(&conn.next_write) {
                    conn.done_bytes -= text.len();
                    conn.next_write += 1;
                    conn.wbuf.extend_from_slice(text.as_bytes());
                }
                while conn.wpos < conn.wbuf.len() {
                    match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                        Ok(0) => {
                            kill = true;
                            break;
                        }
                        Ok(n) => conn.wpos += n,
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => {
                            kill = true;
                            break;
                        }
                    }
                }
                if conn.wpos >= conn.wbuf.len() {
                    conn.wbuf.clear();
                    conn.wpos = 0;
                } else if conn.wpos > WRITE_COMPACT {
                    conn.wbuf.drain(..conn.wpos);
                    conn.wpos = 0;
                }
                if !kill
                    && conn.eof
                    && conn.awaiting == 0
                    && conn.done.is_empty()
                    && conn.wbuf.is_empty()
                {
                    // conversational contract fulfilled: every line
                    // read has been answered and flushed
                    kill = true;
                }
            }
            if kill {
                self.kill_conn(slot);
            } else {
                self.update_interest(slot);
            }
        }

        /// Tear one connection down. The slot is only recycled once no
        /// in-flight batch still references it (the generation stamp
        /// protects the interim).
        fn kill_conn(&mut self, slot: usize) {
            let gen = {
                let conn = match self.conns.get_mut(slot).and_then(Option::as_mut) {
                    Some(c) => c,
                    None => return,
                };
                if conn.dead {
                    return;
                }
                conn.dead = true;
                let _ = self.epoll.del(conn.stream.as_raw_fd());
                let _ = conn.stream.shutdown(Shutdown::Both);
                conn.gen
            };
            // abandon this connection's queued-but-unformed lines
            let mut dropped = 0usize;
            self.pending.retain(|i| {
                if i.slot == slot && i.gen == gen {
                    dropped += 1;
                    false
                } else {
                    true
                }
            });
            let awaiting = match self.conns.get_mut(slot).and_then(Option::as_mut) {
                Some(c) => {
                    c.awaiting = c.awaiting.saturating_sub(dropped);
                    c.awaiting
                }
                None => 0,
            };
            if awaiting == 0 {
                self.free_slot(slot);
            }
        }

        fn free_slot(&mut self, slot: usize) {
            if self.conns.get_mut(slot).and_then(Option::take).is_some() {
                self.n_conns = self.n_conns.saturating_sub(1);
                self.free.push(slot);
            }
        }

        /// Reconcile a connection's epoll interest with its state:
        /// readable unless EOF'd, draining, deferred, or over the
        /// write-buffer cap (read backpressure); writable while bytes
        /// are owed.
        fn update_interest(&mut self, slot: usize) {
            let (fd, gen, want, have) = {
                let conn = match self.conns.get(slot).and_then(Option::as_ref) {
                    Some(c) if !c.dead => c,
                    _ => return,
                };
                let mut want = 0u32;
                if !conn.eof
                    && !self.draining
                    && conn.defer_until.is_none()
                    && conn.backlog() < self.cfg.write_buf_cap.max(1)
                {
                    want |= EPOLLIN;
                }
                if conn.wpos < conn.wbuf.len() {
                    want |= EPOLLOUT;
                }
                (conn.stream.as_raw_fd(), conn.gen, want, conn.interest)
            };
            if want != have && self.epoll.modify(fd, want, token_for(slot, gen)).is_ok() {
                if let Some(c) = self.conns.get_mut(slot).and_then(Option::as_mut) {
                    c.interest = want;
                }
            }
        }

        fn set_listener_interest(&mut self, on: bool) {
            if on == self.listener_armed {
                return;
            }
            let events = if on { EPOLLIN } else { 0 };
            if self
                .epoll
                .modify(self.listener.as_raw_fd(), events, TOKEN_LISTENER)
                .is_ok()
            {
                self.listener_armed = on;
            }
        }

        /// Fire expired timers: re-arm accept after an fd-exhaustion
        /// backoff, resume reads on `conn.slow`-deferred connections.
        fn resume_timers(&mut self) {
            let now = Instant::now();
            if matches!(self.accept_resume, Some(t) if now >= t) {
                self.accept_resume = None;
                self.set_listener_interest(true);
                self.accept_ready();
            }
            let resumed: Vec<usize> = self
                .conns
                .iter()
                .enumerate()
                .filter_map(|(i, c)| match c {
                    Some(c) if !c.dead && matches!(c.defer_until, Some(t) if now >= t) => {
                        Some(i)
                    }
                    _ => None,
                })
                .collect();
            for slot in resumed {
                if let Some(c) = self.conns.get_mut(slot).and_then(Option::as_mut) {
                    c.defer_until = None;
                }
                self.update_interest(slot);
                // level-triggered epoll only reports *new* readiness;
                // bytes that arrived while deferred are already waiting
                self.do_read(slot);
            }
        }

        /// Shutdown requested: stop accepting and reading, dispatch
        /// everything pending, and wait (bounded) for owed responses to
        /// flush.
        fn begin_drain(&mut self) {
            self.draining = true;
            self.drain_deadline = Some(Instant::now() + DRAIN_GRACE);
            self.set_listener_interest(false);
            for slot in 0..self.conns.len() {
                self.update_interest(slot);
            }
        }

        fn drain_finished(&self) -> bool {
            if !self.draining {
                return false;
            }
            if matches!(self.drain_deadline, Some(t) if Instant::now() >= t) {
                // grace expired: a client that never reads its
                // responses does not get to pin the listener
                return true;
            }
            if !self.pending.is_empty() || self.inflight > 0 {
                return false;
            }
            self.conns.iter().flatten().all(|c| {
                c.dead || (c.awaiting == 0 && c.done.is_empty() && c.wbuf.len() == c.wpos)
            })
        }
    }

    /// Read every available chunk off one connection (bounded rounds;
    /// level-triggered epoll re-reports leftovers), then frame complete
    /// lines. Returns the framed events and a hard error if the socket
    /// failed mid-read.
    fn drain_socket(conn: &mut Conn, max_line: usize) -> (Vec<LineEvent>, Option<String>) {
        let mut hard_error = None;
        let mut chunk = [0u8; READ_CHUNK];
        for _ in 0..READ_ROUNDS {
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.eof = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    if conn.rbuf.len() > max_line + READ_CHUNK {
                        // already provably oversized: resync via the
                        // framer before buffering more
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    hard_error = Some(format!("{e}"));
                    break;
                }
            }
        }
        let mut events = split_lines(conn, max_line);
        if conn.eof && hard_error.is_none() && !conn.discarding && !conn.rbuf.is_empty() {
            // a final unterminated line at a clean close is served —
            // the buffered framer does the same at EOF
            let buf = std::mem::take(&mut conn.rbuf);
            conn.scanned = 0;
            match String::from_utf8(buf) {
                Ok(s) => events.push(LineEvent::Line(s)),
                Err(_) => events.push(LineEvent::BadUtf8),
            }
        }
        (events, hard_error)
    }

    /// Frame complete lines out of `conn.rbuf`. Invariants shared with
    /// the buffered framer ([`super::super::read_request_line`]): a
    /// line of exactly `max_line` bytes passes, one byte more is
    /// answered as oversized with the first `max_line` bytes retained
    /// for the id echo, and the stream resynchronizes at the next
    /// newline.
    fn split_lines(conn: &mut Conn, max_line: usize) -> Vec<LineEvent> {
        let mut events = Vec::new();
        loop {
            let nl = conn.rbuf[conn.scanned..].iter().position(|&b| b == b'\n');
            match nl {
                Some(off) => {
                    let pos = conn.scanned + off;
                    if conn.discarding {
                        // tail of an oversized line: drop to the
                        // newline and resume framing
                        conn.rbuf.drain(..=pos);
                        conn.scanned = 0;
                        conn.discarding = false;
                        continue;
                    }
                    if pos > max_line {
                        events.push(LineEvent::Oversized(conn.rbuf[..max_line].to_vec()));
                    } else {
                        match std::str::from_utf8(&conn.rbuf[..pos]) {
                            Ok(s) => events.push(LineEvent::Line(s.to_string())),
                            Err(_) => {
                                events.push(LineEvent::BadUtf8);
                                conn.rbuf.clear();
                                conn.scanned = 0;
                                return events;
                            }
                        }
                    }
                    conn.rbuf.drain(..=pos);
                    conn.scanned = 0;
                }
                None => {
                    if conn.discarding {
                        conn.rbuf.clear();
                        conn.scanned = 0;
                    } else if conn.rbuf.len() > max_line {
                        events.push(LineEvent::Oversized(conn.rbuf[..max_line].to_vec()));
                        conn.rbuf.clear();
                        conn.scanned = 0;
                        conn.discarding = true;
                    } else {
                        conn.scanned = conn.rbuf.len();
                    }
                    return events;
                }
            }
        }
    }
}

#[cfg(all(test, target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::super::testutil::toy_store;
    use super::super::{Service, ServiceConfig};
    use super::{serve_reactor, sys, ReactorConfig};
    use crate::gpusim::registry::builtins;
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::Arc;

    fn toy_service() -> Service {
        let store = toy_store(&[("k40c", 2e-9, 5e-6)]);
        Service::new(store, builtins().clone(), ServiceConfig::default()).unwrap()
    }

    fn spawn(
        svc: &Arc<Service>,
        cfg: ReactorConfig,
    ) -> (std::net::SocketAddr, std::thread::JoinHandle<crate::report::ServiceSummary>) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
        let addr = listener.local_addr().unwrap();
        let svc = Arc::clone(svc);
        let handle = std::thread::spawn(move || {
            serve_reactor(&svc, listener, cfg).expect("serve_reactor")
        });
        (addr, handle)
    }

    /// Send `lines` conversationally; return the response lines.
    fn client(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
        let stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut stream = stream;
        let mut out = Vec::new();
        for line in lines {
            writeln!(stream, "{line}").expect("send");
            stream.flush().expect("flush");
            let mut resp = String::new();
            reader.read_line(&mut resp).expect("recv");
            out.push(resp.trim_end().to_string());
        }
        out
    }

    /// The syscall layer end to end: register, observe readiness with
    /// both a zero and a blocking-with-data timeout, deregister.
    #[test]
    fn epoll_reports_readiness() {
        use std::os::unix::io::AsRawFd;
        let ep = sys::Epoll::new().unwrap();
        let (mut a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        ep.add(b.as_raw_fd(), sys::EPOLLIN, 7).unwrap();
        let mut evs = [sys::EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0, "nothing readable yet");
        a.write_all(&[1]).unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].data(), 7);
        assert_ne!(evs[0].events() & sys::EPOLLIN, 0);
        // re-arm with different interest, then deregister
        ep.modify(b.as_raw_fd(), sys::EPOLLIN | sys::EPOLLOUT, 9).unwrap();
        ep.del(b.as_raw_fd()).unwrap();
    }

    /// The reactor serves the conversational contract and drains
    /// deterministically on shutdown, with the same accounting the
    /// threaded transport produces for this stream.
    #[test]
    fn reactor_serves_and_drains_on_shutdown() {
        let svc = Arc::new(toy_service());
        let (addr, server) = spawn(&svc, ReactorConfig::default());

        let lines: Vec<String> = (0..4)
            .map(|i| format!(r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "a"}}"#))
            .collect();
        let responses = client(addr, &lines);
        assert_eq!(responses.len(), 4);
        for (i, r) in responses.iter().enumerate() {
            let j = Json::parse(r).unwrap();
            assert!(j.get("error").is_none(), "{r}");
            assert_eq!(j.get_f64("id"), Some(i as f64));
        }

        let bye = client(addr, &[r#"{"cmd": "shutdown", "id": "drain"}"#.to_string()]);
        let j = Json::parse(&bye[0]).unwrap();
        assert_eq!(j.get_str("ok"), Some("shutdown"));
        let summary = server.join().expect("server thread");
        assert!(svc.shutdown_requested());
        assert_eq!(summary.requests, 5);
        assert_eq!(summary.errors, 0);
    }

    /// Above the connection cap the reactor answers with the same
    /// one-line overload error the threaded guard produces, and the
    /// shed is counted.
    #[test]
    fn connection_guard_sheds_over_cap() {
        let svc = Arc::new(toy_service());
        let cfg = ReactorConfig { max_conns: 1, ..ReactorConfig::default() };
        let (addr, server) = spawn(&svc, cfg);

        // first connection occupies the only slot (a request proves it
        // is fully installed before the second connect)
        let held = TcpStream::connect(addr).expect("held connect");
        let mut held_reader = BufReader::new(held.try_clone().unwrap());
        let mut held = held;
        writeln!(held, r#"{{"device": "k40c", "kernel": "fd5", "case": "a"}}"#).unwrap();
        let mut first = String::new();
        held_reader.read_line(&mut first).unwrap();
        assert!(Json::parse(first.trim_end()).unwrap().get("error").is_none());

        // second connection: guard response, then close
        let over = TcpStream::connect(addr).expect("over connect");
        let mut over_reader = BufReader::new(over);
        let mut line = String::new();
        over_reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim_end()).unwrap();
        assert_eq!(j.get_str("reason"), Some("overloaded"), "{line}");
        assert!(j.get_str("error").unwrap().contains("at capacity"));
        let mut rest = String::new();
        assert_eq!(over_reader.read_line(&mut rest).unwrap(), 0, "guard closes");

        writeln!(held, r#"{{"cmd": "shutdown"}}"#).unwrap();
        let mut bye = String::new();
        held_reader.read_line(&mut bye).unwrap();
        let summary = server.join().expect("server thread");
        assert_eq!(summary.shed, 1);
    }

    /// Pipelined lines from one client all come back, in order — the
    /// reorder buffer and batch formation preserve the stream contract
    /// even when lines land in different formed batches.
    #[test]
    fn pipelined_lines_come_back_in_order() {
        let svc = Arc::new(toy_service());
        let cfg = ReactorConfig { batch_cap: 3, ..ReactorConfig::default() };
        let (addr, server) = spawn(&svc, cfg);

        let stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut stream = stream;
        for i in 0..10 {
            writeln!(
                stream,
                r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "a"}}"#
            )
            .unwrap();
        }
        stream.flush().unwrap();
        for i in 0..10 {
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let j = Json::parse(resp.trim_end()).unwrap();
            assert_eq!(j.get_f64("id"), Some(i as f64), "{resp}");
        }

        writeln!(stream, r#"{{"cmd": "shutdown"}}"#).unwrap();
        let mut bye = String::new();
        reader.read_line(&mut bye).unwrap();
        let summary = server.join().expect("server thread");
        assert_eq!(summary.requests, 11);
        assert_eq!(summary.errors, 0);
    }
}
