//! Sharded, structurally-keyed property cache for the prediction
//! service.
//!
//! The harness's per-campaign [`crate::harness::PropsCache`] keys on
//! kernel *name* + group shape and lives for one campaign; the service
//! needs a long-lived, concurrently shared cache that also recognizes
//! *inline* kernels clients submit under arbitrary names. Keys are
//! therefore the structural kernel hash ([`super::hash::structural_hash`])
//! plus the extraction options, and the map is sharded: each shard is an
//! independent mutex, so worker threads handling a batch only contend
//! when their kernels land in the same shard.
//!
//! A miss extracts *under the shard lock*: concurrent requests for the
//! same new kernel serialize, every later one observes a hit, and the
//! hit/miss counters are deterministic for a given request stream
//! (asserted by `benches/serve.rs`).
//!
//! Keying has one subtlety: `stats::extract` uses its classification
//! binding to bucket accesses into stride classes, and for the library
//! kernels those classes are *structural* (size sweeps never change
//! them), so named-kernel entries share one extraction across all size
//! cases and devices. Client-submitted inline kernels carry no such
//! guarantee — a parameter-dependent array stride can legitimately
//! classify differently at different sizes — so inline lookups salt
//! the key with a digest of the classification binding
//! (`env_fingerprint`): a repeated request still hits, but a different
//! size never inherits another size's classification.

use super::hash::structural_hash;
use crate::lpir::Kernel;
use crate::stats::{extract, ExtractOpts, KernelProps};
use crate::util::fnv::Fnv64;
use crate::util::intern::Env;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

const SHARDS: usize = 16;

/// Cache key: structural hash + the extraction options that shaped the
/// symbolic counts (the whole struct, so new option fields extend the
/// key automatically) + the classification-binding salt (0 for trusted
/// structural kernels, an env digest for untrusted bindings).
type Key = (u64, ExtractOpts, u64);

/// Digest of a classification binding (sorted name/value pairs).
pub fn env_fingerprint(env: &Env) -> u64 {
    let mut binds: Vec<(&str, i64)> = env.iter().map(|(s, v)| (s.as_str(), v)).collect();
    binds.sort();
    let mut h = Fnv64::new();
    h.write_u64(binds.len() as u64);
    for (name, v) in binds {
        h.write_str(name);
        h.write_i64(v);
    }
    h.finish()
}

/// A concurrently shared symbolic-extraction cache.
pub struct SharedPropsCache {
    shards: Vec<Mutex<BTreeMap<Key, Arc<KernelProps>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for SharedPropsCache {
    fn default() -> Self {
        SharedPropsCache {
            shards: (0..SHARDS).map(|_| Mutex::new(BTreeMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }
}

impl SharedPropsCache {
    pub fn new() -> SharedPropsCache {
        SharedPropsCache::default()
    }

    /// Extracted properties for a kernel, from cache when its structure
    /// has been seen before. Returns `(props, hit)`.
    ///
    /// `env_keyed` selects the keying mode (see module docs): `false`
    /// for library kernels whose stride classes are size-structural
    /// (one entry serves every size case and device), `true` for
    /// untrusted inline kernels (the classification binding joins the
    /// key, so differently-sized requests never share a
    /// classification).
    pub fn props_for(
        &self,
        kernel: &Kernel,
        classify_env: &Env,
        opts: ExtractOpts,
        env_keyed: bool,
    ) -> Result<(Arc<KernelProps>, bool), String> {
        let key = (
            structural_hash(kernel),
            opts,
            if env_keyed { env_fingerprint(classify_env) } else { 0 },
        );
        let shard = &self.shards[(key.0 as usize) % SHARDS];
        let mut map = shard.lock().unwrap();
        if let Some(p) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((Arc::clone(p), true));
        }
        // extract under the shard lock: the first requester pays, every
        // concurrent duplicate waits and then hits
        let props = Arc::new(extract(kernel, classify_env, opts)?);
        map.insert(key, Arc::clone(&props));
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok((props, false))
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct (kernel structure, options) entries currently cached.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, DType, Expr, Layout};
    use crate::qpoly::{env, LinExpr};

    fn scale_kernel(name: &str, array: &str) -> Kernel {
        KernelBuilder::new(name, &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array(array, DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![gid_lin_1d(256)]),
                Expr::mul(Expr::lit(2.0), Expr::load(array, vec![gid_lin_1d(256)])),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap()
    }

    #[test]
    fn structural_sharing_across_names() {
        let cache = SharedPropsCache::new();
        let e = env(&[("n", 1 << 16)]);
        let (_, hit) = cache
            .props_for(&scale_kernel("k1", "a"), &e, ExtractOpts::default(), false)
            .unwrap();
        assert!(!hit);
        // same structure under different kernel/array names: a hit
        let (_, hit) = cache
            .props_for(&scale_kernel("another", "buf"), &e, ExtractOpts::default(), false)
            .unwrap();
        assert!(hit);
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
    }

    #[test]
    fn extraction_options_split_entries() {
        let cache = SharedPropsCache::new();
        let e = env(&[("n", 1 << 16)]);
        let k = scale_kernel("k", "a");
        cache.props_for(&k, &e, ExtractOpts::default(), false).unwrap();
        let (_, hit) = cache
            .props_for(
                &k,
                &e,
                ExtractOpts { collapse_utilization: true, ..Default::default() },
                false,
            )
            .unwrap();
        assert!(!hit, "different extraction options must not share entries");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn env_keyed_lookups_split_by_binding_but_repeat_hits() {
        let cache = SharedPropsCache::new();
        let k = scale_kernel("inline_k", "a");
        let small = env(&[("n", 2)]);
        let big = env(&[("n", 1 << 20)]);
        // untrusted inline path: each distinct binding classifies afresh
        let (_, hit) = cache.props_for(&k, &small, ExtractOpts::default(), true).unwrap();
        assert!(!hit);
        let (_, hit) = cache.props_for(&k, &big, ExtractOpts::default(), true).unwrap();
        assert!(!hit, "a different size must not inherit another size's classification");
        // ...while the identical request still hits
        let (_, hit) = cache.props_for(&k, &big, ExtractOpts::default(), true).unwrap();
        assert!(hit);
        // and env-keyed entries never alias the structural entry
        let (_, hit) = cache.props_for(&k, &big, ExtractOpts::default(), false).unwrap();
        assert!(!hit);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn shared_arc_points_at_one_extraction() {
        let cache = SharedPropsCache::new();
        let e = env(&[("n", 4096)]);
        let (p1, _) = cache
            .props_for(&scale_kernel("k", "a"), &e, ExtractOpts::default(), false)
            .unwrap();
        let (p2, _) = cache
            .props_for(&scale_kernel("k", "a"), &e, ExtractOpts::default(), false)
            .unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
    }
}
