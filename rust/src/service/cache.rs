//! Sharded, structurally-keyed, eviction-bounded property cache for the
//! prediction engine.
//!
//! The harness's per-campaign [`crate::harness::PropsCache`] keys on
//! kernel *name* + group shape and lives for one campaign; the serving
//! path needs a long-lived, concurrently shared cache that also
//! recognizes *inline* kernels clients submit under arbitrary names.
//! Keys are therefore the structural kernel hash
//! ([`super::hash::structural_hash`]) plus the extraction options, and
//! the map is sharded: each shard is an independent mutex, so worker
//! threads handling a batch only contend when their kernels land in the
//! same shard.
//!
//! A miss extracts *under the shard lock*: concurrent requests for the
//! same new kernel serialize, every later one observes a hit, and the
//! hit/miss counters are deterministic for a given request stream
//! (asserted by `benches/serve.rs`).
//!
//! **Eviction.** Each shard is capacity-bounded with a second-chance
//! (clock) policy: a hit sets the entry's referenced bit; when a full
//! shard needs room, the clock hand sweeps its ring, clearing bits
//! until it finds an unreferenced entry to evict. Entries a live
//! workload keeps touching therefore survive churn from one-off inline
//! kernels, and a hostile client cycling unique kernel structures can
//! grow the cache no further than its configured capacity. Evictions
//! are counted ([`SharedPropsCache::evictions`]) and surface in the
//! service summary and `BENCH_serve.json`.
//!
//! Keying has one subtlety: `stats::extract` uses its classification
//! binding to bucket accesses into stride classes, and for the library
//! kernels those classes are *structural* (size sweeps never change
//! them), so named-kernel entries share one extraction across all size
//! cases and devices. Client-submitted inline kernels carry no such
//! guarantee — a parameter-dependent array stride can legitimately
//! classify differently at different sizes — so inline lookups salt
//! the key with a digest of the classification binding
//! (`env_fingerprint`): a repeated request still hits, but a different
//! size never inherits another size's classification.

use super::hash::structural_hash;
use crate::lpir::Kernel;
use crate::obs::span::Span;
use crate::obs::Counter;
use crate::stats::{extract, ExtractOpts, KernelProps};
use crate::util::fnv::Fnv64;
use crate::util::intern::Env;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

const SHARDS: usize = 16;

/// Default total capacity (entries across all shards). Sized so the
/// whole evaluation zoo, every measurement class and a healthy inline
/// population fit without eviction, while a hostile unique-kernel
/// stream stays bounded at a few MB of symbolic counts.
pub const DEFAULT_CAPACITY: usize = 4096;

/// Cache key: structural hash + the extraction options that shaped the
/// symbolic counts (the whole struct, so new option fields extend the
/// key automatically) + the classification-binding salt (0 for trusted
/// structural kernels, an env digest for untrusted bindings).
type Key = (u64, ExtractOpts, u64);

/// Digest of a classification binding (sorted name/value pairs).
pub fn env_fingerprint(env: &Env) -> u64 {
    let mut binds: Vec<(&str, i64)> = env.iter().map(|(s, v)| (s.as_str(), v)).collect();
    binds.sort();
    let mut h = Fnv64::new();
    h.write_u64(binds.len() as u64);
    for (name, v) in binds {
        h.write_str(name);
        h.write_i64(v);
    }
    h.finish()
}

/// One cached extraction plus its second-chance referenced bit.
struct Entry {
    props: Arc<KernelProps>,
    referenced: bool,
}

/// One capacity-bounded shard: the lookup map plus the clock ring the
/// eviction hand sweeps (insertion order). Evicted keys become `None`
/// tombstones — `Vec::remove`'s O(capacity) shift made every eviction a
/// linear scan under a hostile unique-structure stream — and a periodic
/// compaction (triggered when dead slots outnumber live ones) rebuilds
/// the ring in one pass, keeping eviction amortized O(1) while
/// preserving sweep order and the hand's rotational position.
#[derive(Default)]
struct Shard {
    map: BTreeMap<Key, Entry>,
    ring: Vec<Option<Key>>,
    hand: usize,
    tombstones: usize,
}

impl Shard {
    /// Second-chance eviction: sweep from the hand, clearing referenced
    /// bits; evict the first unreferenced entry. Terminates within two
    /// passes over live slots (the first pass clears every bit it
    /// crosses); every ring operation is O(1).
    fn evict_one(&mut self) {
        if self.map.is_empty() {
            return;
        }
        loop {
            if self.hand >= self.ring.len() {
                self.hand = 0;
            }
            let Some(key) = self.ring[self.hand] else {
                self.hand += 1; // skip tombstone
                continue;
            };
            let Some(e) = self.map.get_mut(&key) else {
                // defensive: a ring key without a live entry becomes a
                // tombstone instead of wedging the sweep
                self.ring[self.hand] = None;
                self.tombstones += 1;
                self.hand += 1;
                continue;
            };
            if e.referenced {
                e.referenced = false;
                self.hand += 1;
            } else {
                self.map.remove(&key);
                self.ring[self.hand] = None;
                self.tombstones += 1;
                self.hand += 1;
                return;
            }
        }
    }

    /// Append a freshly inserted key to the ring, compacting first the
    /// moment tombstones outnumber live slots (amortized O(1): each
    /// compaction is one pass that removes at least half the ring, and
    /// every removed slot paid O(1) when it was tombstoned).
    fn push_ring(&mut self, key: Key) {
        self.ring.push(Some(key));
        if self.tombstones * 2 > self.ring.len() {
            self.compact();
        }
    }

    /// Drop tombstones in one pass, preserving sweep order; the hand
    /// follows its element (or the next live slot after it) to its new
    /// position.
    fn compact(&mut self) {
        let old = std::mem::take(&mut self.ring);
        let hand = self.hand;
        self.ring = Vec::with_capacity(old.len().saturating_sub(self.tombstones));
        self.hand = 0;
        for (i, slot) in old.into_iter().enumerate() {
            if i == hand {
                self.hand = self.ring.len();
            }
            if slot.is_some() {
                self.ring.push(slot);
            }
        }
        self.tombstones = 0;
    }
}

/// Shard lock that survives a poisoned peer: an extraction that
/// panicked on another thread must not wedge every later lookup that
/// hashes into the same shard.
fn locked(m: &Mutex<Shard>) -> std::sync::MutexGuard<'_, Shard> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A concurrently shared, eviction-bounded symbolic-extraction cache,
/// optionally layered over a persistent [`super::diskcache::PropsCacheFile`]:
/// an in-memory miss consults the file's preloaded entries before paying
/// for extraction (counted as a `disk_hit`, returned as a cache hit),
/// and every fresh extraction is appended so a restarted or scaled-out
/// instance starts warm. With a file attached the conservation
/// invariant generalizes to `misses + disk_hits == len + evictions`.
pub struct SharedPropsCache {
    shards: Vec<Mutex<Shard>>,
    /// per-shard entry bound (total capacity ≈ `SHARDS ×` this)
    per_shard_cap: usize,
    hits: Counter,
    misses: Counter,
    evictions: Counter,
    disk_hits: Counter,
    persist: Option<Arc<super::diskcache::PropsCacheFile>>,
}

impl Default for SharedPropsCache {
    fn default() -> Self {
        SharedPropsCache::with_capacity(DEFAULT_CAPACITY)
    }
}

impl SharedPropsCache {
    pub fn new() -> SharedPropsCache {
        SharedPropsCache::default()
    }

    /// A cache bounded to roughly `capacity` total entries (rounded up
    /// to a multiple of the shard count; at least one entry per shard —
    /// the hot entry of a request being answered can never be evicted
    /// out from under it).
    pub fn with_capacity(capacity: usize) -> SharedPropsCache {
        SharedPropsCache {
            shards: (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            per_shard_cap: capacity.div_ceil(SHARDS).max(1),
            hits: Counter::new(),
            misses: Counter::new(),
            evictions: Counter::new(),
            disk_hits: Counter::new(),
            persist: None,
        }
    }

    /// Layer a persistent extraction-cache file under this cache. Only
    /// lookups whose [`ExtractOpts`] match the file's header go through
    /// the file (the header pins one option set; mismatched lookups
    /// simply skip the layer).
    pub fn attach_persist(&mut self, file: Arc<super::diskcache::PropsCacheFile>) {
        self.persist = Some(file);
    }

    /// The total entry bound (`SHARDS ×` the per-shard capacity).
    pub fn capacity(&self) -> usize {
        self.per_shard_cap * SHARDS
    }

    /// Extracted properties for a kernel, from cache when its structure
    /// has been seen before. Returns `(props, hit)`.
    ///
    /// `env_keyed` selects the keying mode (see module docs): `false`
    /// for library kernels whose stride classes are size-structural
    /// (one entry serves every size case and device), `true` for
    /// untrusted inline kernels (the classification binding joins the
    /// key, so differently-sized requests never share a
    /// classification).
    pub fn props_for(
        &self,
        kernel: &Kernel,
        classify_env: &Env,
        opts: ExtractOpts,
        env_keyed: bool,
    ) -> Result<(Arc<KernelProps>, bool), String> {
        let key = (
            structural_hash(kernel),
            opts,
            if env_keyed { env_fingerprint(classify_env) } else { 0 },
        );
        let shard = &self.shards[(key.0 as usize) % SHARDS];
        let mut shard = locked(shard);
        if let Some(e) = shard.map.get_mut(&key) {
            e.referenced = true;
            self.hits.inc();
            return Ok((Arc::clone(&e.props), true));
        }
        // in-memory miss: consult the persistent layer (a restarted
        // instance warm-starts from its predecessor's extractions),
        // else extract under the shard lock — the first requester pays,
        // every concurrent duplicate waits and then hits — and append
        // the fresh extraction for the next instance
        let persist = self.persist.as_ref().filter(|f| f.opts() == opts);
        let (props, from_disk) = match persist.and_then(|f| f.lookup(key.0, key.2)) {
            Some(p) => (p, true),
            None => {
                // the expensive symbolic pass gets its own span (nested
                // under the engine's cache-lookup span when tracing)
                let _sp = Span::child("engine.extract");
                let p = Arc::new(extract(kernel, classify_env, opts)?);
                if let Some(f) = persist {
                    f.append(key.0, key.2, &p);
                }
                (p, false)
            }
        };
        if shard.map.len() >= self.per_shard_cap {
            shard.evict_one();
            self.evictions.inc();
        }
        shard.map.insert(key, Entry { props: Arc::clone(&props), referenced: false });
        shard.push_ring(key);
        if from_disk {
            self.disk_hits.inc();
        } else {
            self.misses.inc();
        }
        // a disk hit skipped extraction, so it reports as a hit
        Ok((props, from_disk))
    }

    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// In-memory misses answered from the persistent file (extraction
    /// skipped). Zero unless a file is attached.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.get()
    }

    /// Entries evicted by the second-chance policy so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.get()
    }

    /// Distinct (kernel structure, options) entries currently cached.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| locked(s).map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, DType, Expr, Layout};
    use crate::qpoly::{env, LinExpr};

    fn scale_kernel(name: &str, array: &str) -> Kernel {
        sized_kernel(name, array, 256)
    }

    /// A copy-scale kernel whose group width is part of its structure —
    /// distinct `g` values produce distinct structural hashes, which the
    /// eviction tests use to generate arbitrarily many cache entries.
    fn sized_kernel(name: &str, array: &str, g: i64) -> Kernel {
        KernelBuilder::new(name, &["n"])
            .group_dims_1d(LinExpr::var("n"), g)
            .global_array(array, DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![gid_lin_1d(g)]),
                Expr::mul(Expr::lit(2.0), Expr::load(array, vec![gid_lin_1d(g)])),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap()
    }

    #[test]
    fn structural_sharing_across_names() {
        let cache = SharedPropsCache::new();
        let e = env(&[("n", 1 << 16)]);
        let (_, hit) = cache
            .props_for(&scale_kernel("k1", "a"), &e, ExtractOpts::default(), false)
            .unwrap();
        assert!(!hit);
        // same structure under different kernel/array names: a hit
        let (_, hit) = cache
            .props_for(&scale_kernel("another", "buf"), &e, ExtractOpts::default(), false)
            .unwrap();
        assert!(hit);
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn extraction_options_split_entries() {
        let cache = SharedPropsCache::new();
        let e = env(&[("n", 1 << 16)]);
        let k = scale_kernel("k", "a");
        cache.props_for(&k, &e, ExtractOpts::default(), false).unwrap();
        let (_, hit) = cache
            .props_for(
                &k,
                &e,
                ExtractOpts { collapse_utilization: true, ..Default::default() },
                false,
            )
            .unwrap();
        assert!(!hit, "different extraction options must not share entries");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn env_keyed_lookups_split_by_binding_but_repeat_hits() {
        let cache = SharedPropsCache::new();
        let k = scale_kernel("inline_k", "a");
        let small = env(&[("n", 2)]);
        let big = env(&[("n", 1 << 20)]);
        // untrusted inline path: each distinct binding classifies afresh
        let (_, hit) = cache.props_for(&k, &small, ExtractOpts::default(), true).unwrap();
        assert!(!hit);
        let (_, hit) = cache.props_for(&k, &big, ExtractOpts::default(), true).unwrap();
        assert!(!hit, "a different size must not inherit another size's classification");
        // ...while the identical request still hits
        let (_, hit) = cache.props_for(&k, &big, ExtractOpts::default(), true).unwrap();
        assert!(hit);
        // and env-keyed entries never alias the structural entry
        let (_, hit) = cache.props_for(&k, &big, ExtractOpts::default(), false).unwrap();
        assert!(!hit);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn shared_arc_points_at_one_extraction() {
        let cache = SharedPropsCache::new();
        let e = env(&[("n", 4096)]);
        let (p1, _) = cache
            .props_for(&scale_kernel("k", "a"), &e, ExtractOpts::default(), false)
            .unwrap();
        let (p2, _) = cache
            .props_for(&scale_kernel("k", "a"), &e, ExtractOpts::default(), false)
            .unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn capacity_bounds_the_cache_and_counts_evictions() {
        // 64 total entries (4 per shard); push far more distinct
        // structures through and the bound must hold exactly
        let cache = SharedPropsCache::with_capacity(64);
        assert_eq!(cache.capacity(), 64);
        let e = env(&[("n", 1 << 16)]);
        let n_structures = 200u64;
        for g in 0..n_structures {
            let k = sized_kernel("churn", "a", 8 + g as i64);
            let (_, hit) = cache.props_for(&k, &e, ExtractOpts::default(), false).unwrap();
            assert!(!hit, "every structure is distinct");
        }
        assert!(cache.len() <= cache.capacity(), "len {} over bound", cache.len());
        assert!(cache.evictions() > 0, "churn past capacity must evict");
        // conservation: everything inserted either lives or was evicted
        assert_eq!(cache.misses(), cache.len() as u64 + cache.evictions());
        assert_eq!(cache.misses(), n_structures);
    }

    #[test]
    fn second_chance_keeps_the_hot_entry_alive_through_churn() {
        let cache = SharedPropsCache::with_capacity(64);
        let e = env(&[("n", 1 << 16)]);
        let hot = sized_kernel("hot", "a", 256);
        cache.props_for(&hot, &e, ExtractOpts::default(), false).unwrap();
        // interleave: churn a distinct structure, then touch the hot
        // one — its referenced bit is always set when the clock sweeps,
        // so it survives every eviction pass
        for g in 0..150 {
            let k = sized_kernel("churn", "a", 300 + g);
            cache.props_for(&k, &e, ExtractOpts::default(), false).unwrap();
            let (_, hit) = cache.props_for(&hot, &e, ExtractOpts::default(), false).unwrap();
            assert!(hit, "hot entry evicted after {g} churn inserts");
        }
        assert!(cache.evictions() > 0, "the churn stream must have evicted");
    }

    #[test]
    fn concurrent_churn_conserves_accounting_at_tiny_capacity() {
        // four threads hammer overlapping structure streams through a
        // one-entry-per-shard cache: hits, misses, evictions and live
        // entries must balance exactly no matter how lookups interleave
        let cache = SharedPropsCache::with_capacity(1);
        assert_eq!(cache.capacity(), SHARDS);
        let e = env(&[("n", 1 << 12)]);
        let threads: i64 = 4;
        let per_thread: i64 = 60;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let cache = &cache;
                let e = e.clone();
                scope.spawn(move || {
                    for i in 0..per_thread {
                        // overlapping streams: distinct threads revisit
                        // the same 40 structures at staggered offsets
                        let g = 8 + (i + 13 * t) % 40;
                        let k = sized_kernel("churn", "a", g);
                        cache.props_for(&k, &e, ExtractOpts::default(), false).unwrap();
                    }
                });
            }
        });
        // every lookup is exactly one hit or one miss...
        assert_eq!(cache.hits() + cache.misses(), (threads * per_thread) as u64);
        // ...and every miss's entry either still lives or was evicted
        assert_eq!(cache.misses(), cache.len() as u64 + cache.evictions());
        assert!(cache.len() <= cache.capacity(), "len {} over bound", cache.len());
        assert!(cache.evictions() > 0, "40 structures through 16 slots must evict");
    }

    #[test]
    fn eviction_ring_stays_bounded_under_hostile_churn() {
        // Regression: eviction used `Vec::remove`, an O(capacity) shift
        // per evicted entry. The tombstone ring must stay bounded (dead
        // slots never outnumber live ones for long) while preserving
        // the eviction accounting exactly.
        let cache = SharedPropsCache::with_capacity(32); // 2 per shard
        let e = env(&[("n", 1 << 12)]);
        let rounds = 400u64;
        for g in 0..rounds {
            let k = sized_kernel("churn", "a", 8 + g as i64);
            let (_, hit) = cache.props_for(&k, &e, ExtractOpts::default(), false).unwrap();
            assert!(!hit);
        }
        for s in &cache.shards {
            let s = locked(s);
            assert!(
                s.ring.len() <= 2 * cache.per_shard_cap + 2,
                "ring grew to {} slots for {} live entries",
                s.ring.len(),
                s.map.len()
            );
            assert_eq!(
                s.ring.iter().filter(|k| k.is_some()).count(),
                s.map.len(),
                "live ring slots must mirror the map"
            );
        }
        assert_eq!(cache.misses(), rounds);
        assert_eq!(cache.misses(), cache.len() as u64 + cache.evictions());
    }

    #[test]
    fn capacity_two_and_three_torture_conserves_and_keeps_hot() {
        // per-shard capacities 2 and 3: the hot entry must survive an
        // interleaved churn stream and the conservation invariant must
        // hold exactly at every capacity
        for cap in [32usize, 48] {
            let cache = SharedPropsCache::with_capacity(cap);
            let e = env(&[("n", 1 << 12)]);
            let hot = sized_kernel("hot", "a", 7);
            cache.props_for(&hot, &e, ExtractOpts::default(), false).unwrap();
            for g in 0..200 {
                let k = sized_kernel("churn", "a", 100 + g);
                cache.props_for(&k, &e, ExtractOpts::default(), false).unwrap();
                let (_, hit) = cache.props_for(&hot, &e, ExtractOpts::default(), false).unwrap();
                assert!(hit, "cap {cap}: hot entry evicted after {g} churn inserts");
            }
            assert!(cache.len() <= cache.capacity(), "cap {cap}: len {}", cache.len());
            assert!(cache.evictions() > 0, "cap {cap}: churn past capacity must evict");
            assert_eq!(cache.misses(), cache.len() as u64 + cache.evictions(), "cap {cap}");
        }
    }

    #[test]
    fn tiny_capacity_still_serves_every_request() {
        // pathological bound: one entry per shard; correctness (the
        // right properties come back) must survive constant eviction
        let cache = SharedPropsCache::with_capacity(1);
        assert_eq!(cache.capacity(), SHARDS);
        let e = env(&[("n", 4096)]);
        for round in 0..3 {
            for g in [64, 128, 256, 512] {
                let k = sized_kernel("t", "a", g);
                let (p, _) = cache.props_for(&k, &e, ExtractOpts::default(), false).unwrap();
                assert_eq!(p.kernel_name, "t", "round {round} g {g}");
            }
        }
        assert!(cache.len() <= cache.capacity());
    }
}
