//! Line-delimited JSON prediction requests.
//!
//! One request per line, one response per line, order preserved:
//!
//! ```json
//! {"id": 1, "device": "k40c", "kernel": "fd5", "case": "b"}
//! {"id": 2, "device": "titan_x", "kernel": "nbody", "env": {"n": 65536}}
//! {"id": 3, "device": "p100", "lpir": { ...kernel spec... }, "env": {"n": 4096}}
//! ```
//!
//! * `device` (required) — a registry device the model store holds
//!   weights for.
//! * `kernel` — a named evaluation-zoo kernel; combined with either
//!   `case` (size-case letter `a`–`d`, default `a`) or an explicit
//!   `env` binding all of the kernel's size parameters.
//! * `lpir` — an inline kernel spec ([`super::spec`]); requires `env`.
//! * `id` — any JSON value, echoed verbatim in the response.

use super::spec;
use crate::lpir::Kernel;
use crate::util::json::Json;

/// What a request asks to have predicted.
#[derive(Clone, Debug)]
pub enum KernelRef {
    /// a named evaluation-zoo kernel (resolved against the device's
    /// capability-derived suite)
    Named { name: String, case: Option<String> },
    /// an inline kernel spec
    Inline(Box<Kernel>),
}

/// A parsed prediction request.
#[derive(Clone, Debug)]
pub struct Request {
    /// echoed back in the response (absent -> no `id` field emitted)
    pub id: Option<Json>,
    pub device: String,
    pub kref: KernelRef,
    /// explicit parameter binding (name -> value), if given
    pub env: Option<Vec<(String, i64)>>,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| format!("bad request JSON: {e}"))?;
        Request::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Request, String> {
        if !matches!(j, Json::Obj(_)) {
            return Err("request must be a JSON object".into());
        }
        let device = j
            .get_str("device")
            .ok_or("request: missing 'device'")?
            .to_string();
        let env = match j.get("env") {
            None => None,
            Some(Json::Obj(m)) => {
                let mut pairs = Vec::with_capacity(m.len());
                for (k, v) in m {
                    match v.as_i64() {
                        Some(n) => pairs.push((k.clone(), n)),
                        None => {
                            return Err(format!(
                                "request: env binding '{k}' must be an integer"
                            ))
                        }
                    }
                }
                Some(pairs)
            }
            Some(_) => return Err("request: 'env' must be an object".into()),
        };
        let kref = match (j.get("kernel"), j.get("lpir")) {
            (Some(_), Some(_)) => {
                return Err("request: give either 'kernel' or 'lpir', not both".into())
            }
            (None, None) => {
                return Err("request: missing 'kernel' (named) or 'lpir' (inline spec)".into())
            }
            (Some(k), None) => {
                let name = k
                    .as_str()
                    .ok_or("request: 'kernel' must be a string name")?
                    .to_string();
                let case = match j.get("case") {
                    None => None,
                    Some(c) => Some(
                        c.as_str()
                            .ok_or("request: 'case' must be a string letter")?
                            .to_string(),
                    ),
                };
                if case.is_some() && env.is_some() {
                    return Err("request: give either 'case' or 'env', not both".into());
                }
                KernelRef::Named { name, case }
            }
            (None, Some(l)) => {
                if j.get("case").is_some() {
                    return Err("request: 'case' only applies to named kernels".into());
                }
                if env.is_none() {
                    return Err("request: inline 'lpir' kernels require 'env'".into());
                }
                KernelRef::Inline(Box::new(spec::kernel_from_json(l)?))
            }
        };
        Ok(Request { id: j.get("id").cloned(), device, kref, env })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_case_request() {
        let r = Request::parse(r#"{"id": 7, "device": "k40c", "kernel": "fd5", "case": "b"}"#)
            .unwrap();
        assert_eq!(r.device, "k40c");
        assert_eq!(r.id, Some(Json::Num(7.0)));
        match r.kref {
            KernelRef::Named { name, case } => {
                assert_eq!(name, "fd5");
                assert_eq!(case.as_deref(), Some("b"));
            }
            _ => panic!("expected a named kernel"),
        }
        assert!(r.env.is_none());
    }

    #[test]
    fn named_env_request() {
        let r = Request::parse(r#"{"device": "titan_x", "kernel": "nbody", "env": {"n": 65536}}"#)
            .unwrap();
        assert!(r.id.is_none());
        assert_eq!(r.env, Some(vec![("n".to_string(), 65536)]));
    }

    #[test]
    fn inline_request_requires_env() {
        let spec = r#"{"params": ["n"],
            "dims": [{"iname": "g0", "tag": "group0", "hi": "n", "tiles": 64},
                     {"iname": "l0", "tag": "local0", "hi": 64}],
            "arrays": [{"name": "o", "dtype": "f32", "shape": ["n"], "output": true}],
            "insns": [{"store": "o", "idx": ["64*g0 + l0"], "expr": {"lit": 1},
                       "within": ["g0", "l0"]}]}"#;
        let line = format!(r#"{{"device": "k40c", "lpir": {spec}, "env": {{"n": 4096}}}}"#);
        let r = Request::parse(&line).unwrap();
        assert!(matches!(r.kref, KernelRef::Inline(_)));
        // missing env -> rejected
        let line = format!(r#"{{"device": "k40c", "lpir": {spec}}}"#);
        assert!(Request::parse(&line).unwrap_err().contains("require 'env'"));
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("[1]").is_err());
        assert!(Request::parse(r#"{"kernel": "fd5"}"#).unwrap_err().contains("device"));
        assert!(Request::parse(r#"{"device": "k40c"}"#).unwrap_err().contains("kernel"));
        assert!(Request::parse(
            r#"{"device": "k40c", "kernel": "fd5", "case": "a", "env": {"n": 1}}"#
        )
        .unwrap_err()
        .contains("not both"));
        assert!(Request::parse(r#"{"device": "k40c", "kernel": "fd5", "env": {"n": 1.5}}"#)
            .unwrap_err()
            .contains("integer"));
    }
}
