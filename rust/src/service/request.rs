//! Line-delimited JSON prediction requests.
//!
//! One request per line, one response per line, order preserved:
//!
//! ```json
//! {"id": 1, "device": "k40c", "kernel": "fd5", "case": "b"}
//! {"id": 2, "device": "titan_x", "kernel": "nbody", "env": {"n": 65536}}
//! {"id": 3, "device": "p100", "lpir": { ...kernel spec... }, "env": {"n": 4096}}
//! {"id": 4, "cmd": "matrix", "kernel": "fd5", "case": "b"}
//! {"id": 5, "cmd": "shutdown"}
//! ```
//!
//! The optional `cmd` field selects the request type:
//!
//! * absent or `"predict"` — a single-device prediction:
//!   * `device` (required) — a registry device the model store holds
//!     weights for;
//!   * `kernel` — a named evaluation-zoo kernel; combined with either
//!     `case` (size-case letter `a`–`d`, default `a`) or an explicit
//!     `env` binding all of the kernel's size parameters;
//!   * `lpir` — an inline kernel spec ([`super::spec`]); requires `env`.
//! * `"matrix"` — a batched device×kernel matrix request: the same
//!   `kernel`/`lpir` + `case`/`env` fields, parsed **once**, predicted
//!   for every device in the optional `devices` array (default: every
//!   device the installed model store holds weights for).
//! * `"shutdown"` — ask the server to stop accepting work and drain
//!   (the threaded TCP listener joins its connections and exits).
//! * `"health"` / `"stats"` — liveness + introspection: store
//!   fingerprint, reloader state, cache/quarantine/breaker counters and
//!   fault-injection tallies. Never touches the prediction path.
//! * `"metrics"` — the unified metrics snapshot as Prometheus-style
//!   exposition text (same snapshot health and stats are built from).
//! * `"trace"` — recent + slow structured spans as JSON (empty unless
//!   the server was started with `--trace`/`--profile`).
//!
//! `id` — any JSON value, echoed verbatim in the response.
//!
//! Predict and matrix requests additionally accept `"deadline_ms"` (a
//! non-negative number): if the request has waited in the server longer
//! than its deadline by the time it is executed, it is answered with a
//! `"reason": "deadline"` error instead of a stale prediction.

use super::spec;
use crate::lpir::Kernel;
use crate::util::json::Json;

/// What a request asks to have predicted.
#[derive(Clone, Debug)]
pub enum KernelRef {
    /// a named evaluation-zoo kernel (resolved against the device's
    /// capability-derived suite)
    Named { name: String, case: Option<String> },
    /// an inline kernel spec
    Inline(Box<Kernel>),
}

/// A parsed single-device prediction request.
#[derive(Clone, Debug)]
pub struct PredictRequest {
    /// echoed back in the response (absent -> no `id` field emitted)
    pub id: Option<Json>,
    pub device: String,
    pub kref: KernelRef,
    /// explicit parameter binding (name -> value), if given
    pub env: Option<Vec<(String, i64)>>,
    /// queue-time budget in milliseconds; `None` = wait forever
    pub deadline_ms: Option<f64>,
}

/// A parsed device×kernel matrix request: one kernel (parsed once),
/// predicted across many devices.
#[derive(Clone, Debug)]
pub struct MatrixRequest {
    pub id: Option<Json>,
    /// explicit target devices; `None` = every device in the store
    pub devices: Option<Vec<String>>,
    pub kref: KernelRef,
    pub env: Option<Vec<(String, i64)>>,
    /// queue-time budget in milliseconds; `None` = wait forever
    pub deadline_ms: Option<f64>,
}

/// Any parsed request line.
#[derive(Clone, Debug)]
pub enum Request {
    Predict(PredictRequest),
    Matrix(MatrixRequest),
    /// drain + stop the serving loop
    Shutdown { id: Option<Json> },
    /// liveness + component status (store, reloader, breakers, faults)
    Health { id: Option<Json> },
    /// counter snapshot (requests, cache, shedding, quarantine)
    Stats { id: Option<Json> },
    /// Prometheus-style exposition of the unified metrics snapshot
    Metrics { id: Option<Json> },
    /// recent + slow structured spans as JSON
    Trace { id: Option<Json> },
}

/// Parse the optional `env` object into (name, value) bindings.
fn parse_env(j: &Json) -> Result<Option<Vec<(String, i64)>>, String> {
    match j.get("env") {
        None => Ok(None),
        Some(Json::Obj(m)) => {
            let mut pairs = Vec::with_capacity(m.len());
            for (k, v) in m {
                match v.as_i64() {
                    Some(n) => pairs.push((k.clone(), n)),
                    None => {
                        return Err(format!(
                            "request: env binding '{k}' must be an integer"
                        ))
                    }
                }
            }
            Ok(Some(pairs))
        }
        Some(_) => Err("request: 'env' must be an object".into()),
    }
}

/// Parse the optional `deadline_ms` budget (non-negative, finite).
fn parse_deadline(j: &Json) -> Result<Option<f64>, String> {
    match j.get("deadline_ms") {
        None => Ok(None),
        Some(d) => match d.as_f64() {
            Some(ms) if ms.is_finite() && ms >= 0.0 => Ok(Some(ms)),
            _ => Err("request: 'deadline_ms' must be a non-negative number".into()),
        },
    }
}

/// Parse the kernel reference (`kernel` + `case`, or inline `lpir`),
/// enforcing the case/env exclusivity rules.
fn parse_kref(j: &Json, env: &Option<Vec<(String, i64)>>) -> Result<KernelRef, String> {
    match (j.get("kernel"), j.get("lpir")) {
        (Some(_), Some(_)) => {
            Err("request: give either 'kernel' or 'lpir', not both".into())
        }
        (None, None) => {
            Err("request: missing 'kernel' (named) or 'lpir' (inline spec)".into())
        }
        (Some(k), None) => {
            let name = k
                .as_str()
                .ok_or("request: 'kernel' must be a string name")?
                .to_string();
            let case = match j.get("case") {
                None => None,
                Some(c) => Some(
                    c.as_str()
                        .ok_or("request: 'case' must be a string letter")?
                        .to_string(),
                ),
            };
            if case.is_some() && env.is_some() {
                return Err("request: give either 'case' or 'env', not both".into());
            }
            Ok(KernelRef::Named { name, case })
        }
        (None, Some(l)) => {
            if j.get("case").is_some() {
                return Err("request: 'case' only applies to named kernels".into());
            }
            if env.is_none() {
                return Err("request: inline 'lpir' kernels require 'env'".into());
            }
            Ok(KernelRef::Inline(Box::new(spec::kernel_from_json(l)?)))
        }
    }
}

impl PredictRequest {
    pub fn from_json(j: &Json) -> Result<PredictRequest, String> {
        let device = j
            .get_str("device")
            .ok_or("request: missing 'device'")?
            .to_string();
        let env = parse_env(j)?;
        let kref = parse_kref(j, &env)?;
        let deadline_ms = parse_deadline(j)?;
        Ok(PredictRequest { id: j.get("id").cloned(), device, kref, env, deadline_ms })
    }
}

impl MatrixRequest {
    pub fn from_json(j: &Json) -> Result<MatrixRequest, String> {
        let devices = match j.get("devices") {
            None => None,
            Some(Json::Arr(items)) => {
                if items.is_empty() {
                    return Err("matrix request: 'devices' must not be empty".into());
                }
                let mut names = Vec::with_capacity(items.len());
                for d in items {
                    names.push(
                        d.as_str()
                            .ok_or("matrix request: 'devices' entries must be strings")?
                            .to_string(),
                    );
                }
                Some(names)
            }
            Some(_) => {
                return Err("matrix request: 'devices' must be an array of names".into())
            }
        };
        if j.get("device").is_some() {
            return Err(
                "matrix request: use 'devices' (array), not 'device' — or drop \
                 'cmd' for a single-device prediction"
                    .into(),
            );
        }
        let env = parse_env(j)?;
        let kref = parse_kref(j, &env)?;
        let deadline_ms = parse_deadline(j)?;
        Ok(MatrixRequest { id: j.get("id").cloned(), devices, kref, env, deadline_ms })
    }
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| format!("bad request JSON: {e}"))?;
        Request::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Request, String> {
        if !matches!(j, Json::Obj(_)) {
            return Err("request must be a JSON object".into());
        }
        match j.get("cmd") {
            None => Ok(Request::Predict(PredictRequest::from_json(j)?)),
            Some(c) => match c.as_str() {
                Some("predict") => Ok(Request::Predict(PredictRequest::from_json(j)?)),
                Some("matrix") => Ok(Request::Matrix(MatrixRequest::from_json(j)?)),
                Some("shutdown") => Ok(Request::Shutdown { id: j.get("id").cloned() }),
                Some("health") => Ok(Request::Health { id: j.get("id").cloned() }),
                Some("stats") => Ok(Request::Stats { id: j.get("id").cloned() }),
                Some("metrics") => Ok(Request::Metrics { id: j.get("id").cloned() }),
                Some("trace") => Ok(Request::Trace { id: j.get("id").cloned() }),
                Some(other) => Err(format!(
                    "request: unknown cmd '{other}' \
                     (predict|matrix|health|stats|metrics|trace|shutdown)"
                )),
                None => Err("request: 'cmd' must be a string".into()),
            },
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn parse_predict(line: &str) -> PredictRequest {
        match Request::parse(line).unwrap() {
            Request::Predict(p) => p,
            other => panic!("expected a predict request, got {other:?}"),
        }
    }

    #[test]
    fn named_case_request() {
        let r = parse_predict(r#"{"id": 7, "device": "k40c", "kernel": "fd5", "case": "b"}"#);
        assert_eq!(r.device, "k40c");
        assert_eq!(r.id, Some(Json::Num(7.0)));
        match r.kref {
            KernelRef::Named { name, case } => {
                assert_eq!(name, "fd5");
                assert_eq!(case.as_deref(), Some("b"));
            }
            _ => panic!("expected a named kernel"),
        }
        assert!(r.env.is_none());
    }

    #[test]
    fn named_env_request() {
        let r =
            parse_predict(r#"{"device": "titan_x", "kernel": "nbody", "env": {"n": 65536}}"#);
        assert!(r.id.is_none());
        assert_eq!(r.env, Some(vec![("n".to_string(), 65536)]));
    }

    #[test]
    fn inline_request_requires_env() {
        let spec = r#"{"params": ["n"],
            "dims": [{"iname": "g0", "tag": "group0", "hi": "n", "tiles": 64},
                     {"iname": "l0", "tag": "local0", "hi": 64}],
            "arrays": [{"name": "o", "dtype": "f32", "shape": ["n"], "output": true}],
            "insns": [{"store": "o", "idx": ["64*g0 + l0"], "expr": {"lit": 1},
                       "within": ["g0", "l0"]}]}"#;
        let line = format!(r#"{{"device": "k40c", "lpir": {spec}, "env": {{"n": 4096}}}}"#);
        let r = parse_predict(&line);
        assert!(matches!(r.kref, KernelRef::Inline(_)));
        // missing env -> rejected
        let line = format!(r#"{{"device": "k40c", "lpir": {spec}}}"#);
        assert!(Request::parse(&line).unwrap_err().contains("require 'env'"));
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("[1]").is_err());
        assert!(Request::parse(r#"{"kernel": "fd5"}"#).unwrap_err().contains("device"));
        assert!(Request::parse(r#"{"device": "k40c"}"#).unwrap_err().contains("kernel"));
        assert!(Request::parse(
            r#"{"device": "k40c", "kernel": "fd5", "case": "a", "env": {"n": 1}}"#
        )
        .unwrap_err()
        .contains("not both"));
        assert!(Request::parse(r#"{"device": "k40c", "kernel": "fd5", "env": {"n": 1.5}}"#)
            .unwrap_err()
            .contains("integer"));
    }

    #[test]
    fn cmd_field_selects_request_type() {
        // explicit predict behaves exactly like the bare form
        let r = Request::parse(
            r#"{"cmd": "predict", "device": "k40c", "kernel": "fd5", "case": "a"}"#,
        )
        .unwrap();
        assert!(matches!(r, Request::Predict(_)));
        // shutdown echoes its id
        match Request::parse(r#"{"cmd": "shutdown", "id": "drain-1"}"#).unwrap() {
            Request::Shutdown { id } => assert_eq!(id, Some(Json::Str("drain-1".into()))),
            other => panic!("expected shutdown, got {other:?}"),
        }
        // unknown and non-string cmds are rejected
        assert!(Request::parse(r#"{"cmd": "reboot"}"#).unwrap_err().contains("unknown cmd"));
        assert!(Request::parse(r#"{"cmd": 3}"#).unwrap_err().contains("must be a string"));
    }

    #[test]
    fn deadline_ms_parses_and_rejects_bad_values() {
        let r = parse_predict(
            r#"{"device": "k40c", "kernel": "fd5", "case": "a", "deadline_ms": 250}"#,
        );
        assert_eq!(r.deadline_ms, Some(250.0));
        let r = parse_predict(r#"{"device": "k40c", "kernel": "fd5"}"#);
        assert!(r.deadline_ms.is_none());
        // zero is legal: "answer only if executed immediately"
        let r = parse_predict(
            r#"{"device": "k40c", "kernel": "fd5", "deadline_ms": 0}"#,
        );
        assert_eq!(r.deadline_ms, Some(0.0));
        for bad in [
            r#"{"device": "k40c", "kernel": "fd5", "deadline_ms": -1}"#,
            r#"{"device": "k40c", "kernel": "fd5", "deadline_ms": "soon"}"#,
        ] {
            assert!(Request::parse(bad).unwrap_err().contains("deadline_ms"));
        }
        // matrix requests take the same budget
        match Request::parse(r#"{"cmd": "matrix", "kernel": "fd5", "deadline_ms": 9.5}"#)
            .unwrap()
        {
            Request::Matrix(m) => assert_eq!(m.deadline_ms, Some(9.5)),
            other => panic!("expected matrix, got {other:?}"),
        }
    }

    #[test]
    fn health_and_stats_cmds_parse() {
        match Request::parse(r#"{"cmd": "health", "id": 12}"#).unwrap() {
            Request::Health { id } => assert_eq!(id, Some(Json::Num(12.0))),
            other => panic!("expected health, got {other:?}"),
        }
        match Request::parse(r#"{"cmd": "stats"}"#).unwrap() {
            Request::Stats { id } => assert!(id.is_none()),
            other => panic!("expected stats, got {other:?}"),
        }
        match Request::parse(r#"{"cmd": "metrics", "id": "m"}"#).unwrap() {
            Request::Metrics { id } => assert_eq!(id, Some(Json::Str("m".into()))),
            other => panic!("expected metrics, got {other:?}"),
        }
        match Request::parse(r#"{"cmd": "trace"}"#).unwrap() {
            Request::Trace { id } => assert!(id.is_none()),
            other => panic!("expected trace, got {other:?}"),
        }
    }

    #[test]
    fn matrix_requests_parse_device_lists_and_reject_device() {
        let m = match Request::parse(
            r#"{"cmd": "matrix", "kernel": "fd5", "case": "b", "id": 4}"#,
        )
        .unwrap()
        {
            Request::Matrix(m) => m,
            other => panic!("expected matrix, got {other:?}"),
        };
        assert!(m.devices.is_none());
        assert_eq!(m.id, Some(Json::Num(4.0)));
        match m.kref {
            KernelRef::Named { ref name, ref case } => {
                assert_eq!(name, "fd5");
                assert_eq!(case.as_deref(), Some("b"));
            }
            _ => panic!("expected a named kernel"),
        }

        let m = match Request::parse(
            r#"{"cmd": "matrix", "devices": ["k40c", "titan_x"], "kernel": "nbody"}"#,
        )
        .unwrap()
        {
            Request::Matrix(m) => m,
            other => panic!("expected matrix, got {other:?}"),
        };
        assert_eq!(
            m.devices,
            Some(vec!["k40c".to_string(), "titan_x".to_string()])
        );

        // the predict-shaped 'device' key is rejected with guidance
        let e = Request::parse(r#"{"cmd": "matrix", "device": "k40c", "kernel": "fd5"}"#)
            .unwrap_err();
        assert!(e.contains("'devices'"), "{e}");
        // empty and non-string device lists are rejected
        assert!(Request::parse(r#"{"cmd": "matrix", "devices": [], "kernel": "fd5"}"#)
            .unwrap_err()
            .contains("must not be empty"));
        assert!(Request::parse(r#"{"cmd": "matrix", "devices": [1], "kernel": "fd5"}"#)
            .unwrap_err()
            .contains("strings"));
        // matrix kernels obey the same case/env exclusivity
        assert!(Request::parse(
            r#"{"cmd": "matrix", "kernel": "fd5", "case": "a", "env": {"n": 1}}"#
        )
        .unwrap_err()
        .contains("not both"));
    }
}
