//! `service` — the batched, cached kernel-runtime prediction server.
//!
//! Everything upstream of this module is a *batch reproduction*
//! pipeline: measure, fit, report. This subsystem turns the fitted
//! model into a queryable artifact, per the ROADMAP north star (serve
//! heavy traffic as fast as the hardware allows):
//!
//! 1. **Artifacts** ([`store`]) — `fit --save models.json` persists one
//!    weight table per device, fingerprinted against the schema, the
//!    device profile and the capability-derived measurement suite;
//!    [`Service::new`] refuses stale artifacts.
//! 2. **Requests** ([`request`]) — line-delimited JSON naming either an
//!    evaluation-zoo kernel or an inline `lpir` kernel spec ([`spec`]).
//! 3. **Caching** ([`cache`]) — symbolic extraction is the expensive
//!    step (milliseconds); results are shared through a sharded cache
//!    keyed by the *structural* kernel hash ([`hash`]), so a warm
//!    request never re-runs extraction and drops straight onto the
//!    compiled [`crate::qpoly::tape::PwTape`] fast path (microseconds).
//! 4. **Batching** ([`Service::serve`]) — requests drain in
//!    deterministic batches onto [`crate::util::executor::par_map`];
//!    responses preserve input order, and per-request latency plus
//!    cache-hit accounting surface in a
//!    [`crate::report::render_service`] summary. Cache hits are
//!    excluded from the extraction-time floor entirely — a hit is a
//!    non-run, not a 0-second run (the exclusion rule
//!    [`crate::harness::Sample::Cached`] /
//!    [`crate::harness::Protocol::reduce_samples`] define and
//!    unit-test).
//!
//! Property vectors are hardware-independent (the cross-machine result
//! of arXiv:1904.09538), so one cached extraction answers queries for
//! *every* registered device; only the weight table is per-device.

pub mod cache;
pub mod hash;
pub mod request;
pub mod spec;
pub mod store;

pub use cache::SharedPropsCache;
pub use request::{KernelRef, Request};
pub use store::{ModelStore, StoredModel};

use crate::gpusim::DeviceRegistry;
use crate::kernels::{self, KernelCase};
use crate::report::ServiceSummary;
use crate::stats::{ExtractOpts, Schema};
use crate::util::executor::{default_workers, par_map};
use crate::util::intern::Env;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Serving configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// requests per batch handed to the executor (order-preserving)
    pub batch: usize,
    /// worker threads per batch
    pub workers: usize,
    /// extraction options (must match how the model was fitted)
    pub extract: ExtractOpts,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { batch: 64, workers: default_workers(), extract: ExtractOpts::default() }
    }
}

/// Once this many latency samples are held, the buffer is decimated
/// (every 2nd sample dropped) and the recording stride doubles — a
/// server answering millions of requests keeps percentile-grade
/// coverage of its whole history in bounded memory.
const LATENCY_CAP: usize = 1 << 14;

#[derive(Default)]
struct LatencyBuf {
    samples: Vec<f64>,
    /// record every `stride`-th observation (doubles on decimation)
    stride: u64,
    seen: u64,
}

impl LatencyBuf {
    fn push(&mut self, us: f64) {
        self.seen += 1;
        let stride = self.stride.max(1);
        if self.seen % stride != 0 {
            return;
        }
        self.samples.push(us);
        if self.samples.len() >= LATENCY_CAP {
            let mut keep = false;
            self.samples.retain(|_| {
                keep = !keep;
                keep
            });
            self.stride = stride * 2;
        }
    }
}

#[derive(Default)]
struct Stats {
    requests: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    latencies_us: Mutex<LatencyBuf>,
    /// exact running floor over every *timed* extraction. Cache hits
    /// contribute nothing — the 0-second-sample pollution that
    /// [`crate::harness::Sample::Cached`] /
    /// [`crate::harness::Protocol::reduce_samples`] define and
    /// unit-test the exclusion rule for — so this is bounded state
    /// with an exact answer, even for miss-heavy inline workloads.
    min_extract_s: Mutex<Option<f64>>,
}

/// The prediction server: a validated model store + device registry +
/// shared props cache, answering requests concurrently.
pub struct Service {
    registry: DeviceRegistry,
    store: ModelStore,
    schema: Schema,
    cache: SharedPropsCache,
    cfg: ServiceConfig,
    /// per-device evaluation-zoo suites, precomputed for every device
    /// the store holds weights for (named-kernel resolution)
    suites: BTreeMap<String, Vec<KernelCase>>,
    stats: Stats,
}

struct Prediction {
    id: Option<Json>,
    device: String,
    kernel: String,
    case: Option<String>,
    predicted_s: f64,
    cache_hit: bool,
    extract_s: Option<f64>,
}

impl Service {
    /// Build a service over a loaded artifact. The store is
    /// staleness-validated against `registry` (profile + suite + schema
    /// fingerprints) before anything is served.
    pub fn new(
        store: ModelStore,
        registry: DeviceRegistry,
        cfg: ServiceConfig,
    ) -> Result<Service, String> {
        let schema = Schema::full();
        store.validate_against(&registry, &schema)?;
        if store.extract != cfg.extract {
            return Err(format!(
                "model artifact was fitted under extraction options {:?} but the \
                 service was configured with {:?} — serve with matching flags or \
                 re-run `fit --save`",
                store.extract, cfg.extract
            ));
        }
        if store.is_empty() {
            return Err("model artifact holds no fitted devices".into());
        }
        let mut suites = BTreeMap::new();
        for device in store.devices() {
            let profile = registry.get(&device).expect("validated above");
            suites.insert(device.clone(), kernels::eval_suite(profile));
        }
        Ok(Service {
            registry,
            store,
            schema,
            cache: SharedPropsCache::new(),
            cfg,
            suites,
            stats: Stats::default(),
        })
    }

    pub fn store(&self) -> &ModelStore {
        &self.store
    }

    pub fn cache(&self) -> &SharedPropsCache {
        &self.cache
    }

    /// Resolve + predict one parsed request.
    fn predict_request(&self, req: &Request) -> Result<Prediction, String> {
        let profile = self
            .registry
            .get(&req.device)
            .ok_or_else(|| format!("unknown device '{}'", req.device))?;
        let sm = self.store.get(&req.device).ok_or_else(|| {
            format!(
                "no fitted model for device '{}' in the artifact (have: {})",
                req.device,
                self.store.devices().join(", ")
            )
        })?;

        // resolve the kernel + parameter binding
        let user_env = |pairs: &[(String, i64)]| {
            let mut e = Env::new();
            for (k, v) in pairs {
                e.insert(k.as_str(), *v);
            }
            e
        };
        let (kernel, env, kname, case_letter) = match &req.kref {
            KernelRef::Named { name, case } => {
                let suite = self.suites.get(&req.device).expect("suites cover store devices");
                let cases: Vec<&KernelCase> =
                    suite.iter().filter(|c| c.kernel.name == *name).collect();
                if cases.is_empty() {
                    let mut known: Vec<&str> = Vec::new();
                    for c in suite {
                        if !known.contains(&c.kernel.name.as_str()) {
                            known.push(&c.kernel.name);
                        }
                    }
                    return Err(format!(
                        "unknown kernel '{name}' (known: {})",
                        known.join(", ")
                    ));
                }
                let (kernel, env, case_letter) = match (case, &req.env) {
                    (Some(letter), _) => {
                        let found = cases
                            .iter()
                            .find(|c| c.label.split('/').nth(1) == Some(letter.as_str()))
                            .ok_or_else(|| {
                                format!("kernel '{name}' has no size case '{letter}' (a-d)")
                            })?;
                        (&found.kernel, found.env.clone(), Some(letter.clone()))
                    }
                    (None, Some(pairs)) => (&cases[0].kernel, user_env(pairs), None),
                    (None, None) => {
                        // default: the smallest (`a`) size case
                        let found = cases
                            .iter()
                            .find(|c| c.label.split('/').nth(1) == Some("a"))
                            .unwrap_or(&cases[0]);
                        (
                            &found.kernel,
                            found.env.clone(),
                            found.label.split('/').nth(1).map(|s| s.to_string()),
                        )
                    }
                };
                (kernel, env, name.clone(), case_letter)
            }
            KernelRef::Inline(k) => (
                k.as_ref(),
                user_env(req.env.as_ref().expect("parser enforces env for inline")),
                k.name.clone(),
                None,
            ),
        };

        // every size parameter must be bound
        for p in &kernel.params {
            if env.get(*p).is_none() {
                return Err(format!("kernel '{kname}' requires parameter '{p}' in env"));
            }
        }
        // reject launches the target device cannot run
        let (gs0, gs1) = kernel.group_size_at(&env)?;
        if gs0 * gs1 > profile.max_group_size as i64 {
            return Err(format!(
                "group size {}x{} exceeds {}'s limit of {}",
                gs0, gs1, profile.name, profile.max_group_size
            ));
        }

        // cached symbolic extraction -> tape evaluation -> inner product.
        // Suite-configured library cases share one entry across sizes
        // and devices (their stride classes are size-structural by
        // construction); any request supplying its *own* binding —
        // inline kernels and named kernels with a user env — is
        // additionally keyed by that binding, so a degenerate size
        // cannot poison the shared classification.
        let env_keyed =
            matches!(&req.kref, KernelRef::Inline(_)) || req.env.is_some();
        let t0 = Instant::now();
        let (props, hit) = self.cache.props_for(kernel, &env, self.cfg.extract, env_keyed)?;
        let extract_s = (!hit).then(|| t0.elapsed().as_secs_f64());
        let v = props.eval(&self.schema, &env)?;
        Ok(Prediction {
            id: req.id.clone(),
            device: req.device.clone(),
            kernel: kname,
            case: case_letter,
            predicted_s: sm.model.predict(&v),
            cache_hit: hit,
            extract_s,
        })
    }

    /// Handle one request line: parse, predict, account, and render the
    /// response object. Never panics on malformed input — errors come
    /// back as `{"error": ...}` responses (echoing `id` when it parsed).
    pub fn respond(&self, line: &str) -> Json {
        let t0 = Instant::now();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let error_resp = |id: Option<&Json>, msg: String| {
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
            let mut pairs = vec![("error", Json::Str(msg))];
            if let Some(id) = id {
                pairs.push(("id", id.clone()));
            }
            Json::obj(pairs)
        };
        let resp = match Request::parse(line) {
            Err(e) => {
                // salvage the id for correlation even when the request
                // is otherwise malformed (documented id-echo contract)
                let id = Json::parse(line).ok().and_then(|j| j.get("id").cloned());
                error_resp(id.as_ref(), e)
            }
            Ok(req) => match self.predict_request(&req) {
                Err(e) => error_resp(req.id.as_ref(), e),
                Ok(p) => {
                    // a cache hit is a non-run: `extract_s` is `None`
                    // (the `harness::Sample::Cached` exclusion rule),
                    // so it contributes nothing to the floor instead
                    // of entering it as a 0-second sample
                    if let Some(t) = p.extract_s {
                        let mut m = self.stats.min_extract_s.lock().unwrap();
                        *m = Some(m.map_or(t, |x| x.min(t)));
                    }
                    let mut pairs = vec![
                        ("device", Json::Str(p.device)),
                        ("kernel", Json::Str(p.kernel)),
                        ("predicted_s", Json::Num(p.predicted_s)),
                        (
                            "cache",
                            Json::Str(if p.cache_hit { "hit".into() } else { "miss".into() }),
                        ),
                    ];
                    if let Some(c) = p.case {
                        pairs.push(("case", Json::Str(c)));
                    }
                    if let Some(id) = p.id {
                        pairs.push(("id", id));
                    }
                    Json::obj(pairs)
                }
            },
        };
        self.stats
            .latencies_us
            .lock()
            .unwrap()
            .push(t0.elapsed().as_secs_f64() * 1e6);
        resp
    }

    #[cfg(test)]
    fn latency_samples_held(&self) -> usize {
        self.stats.latencies_us.lock().unwrap().samples.len()
    }

    /// Handle one deterministic batch: responses come back in request
    /// order regardless of which worker answered which request.
    pub fn run_batch(&self, lines: Vec<String>) -> Vec<Json> {
        if lines.is_empty() {
            return Vec::new();
        }
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        par_map(lines, self.cfg.workers, |l| self.respond(&l))
    }

    /// The piped serving loop (stdin, `--requests` files): read request
    /// lines, drain them in batches of `cfg.batch`, write one response
    /// line per request in order. Returns the run's summary at end of
    /// stream. Batching trades latency for throughput, so this loop is
    /// for EOF-bounded streams; a conversational peer that waits for
    /// each answer before sending more must use
    /// [`Service::serve_interactive`].
    pub fn serve<R: BufRead, W: Write>(
        &self,
        reader: R,
        mut out: W,
    ) -> Result<ServiceSummary, String> {
        self.serve_batched(reader, &mut out, self.cfg.batch)?;
        Ok(self.summary())
    }

    /// The conversational serving loop (TCP connections): every request
    /// line is answered and flushed before the next read, so a client
    /// that blocks on the response never deadlocks against the batch
    /// window. Each request is still accounted as a (size-1) batch.
    pub fn serve_interactive<R: BufRead, W: Write>(
        &self,
        reader: R,
        mut out: W,
    ) -> Result<ServiceSummary, String> {
        self.serve_batched(reader, &mut out, 1)?;
        Ok(self.summary())
    }

    fn serve_batched<R: BufRead>(
        &self,
        reader: R,
        out: &mut impl Write,
        batch: usize,
    ) -> Result<(), String> {
        let mut pending: Vec<String> = Vec::new();
        for line in reader.lines() {
            let line = line.map_err(|e| format!("read request stream: {e}"))?;
            if line.trim().is_empty() {
                continue;
            }
            pending.push(line);
            if pending.len() >= batch.max(1) {
                self.flush(&mut pending, out)?;
            }
        }
        self.flush(&mut pending, out)
    }

    fn flush(&self, pending: &mut Vec<String>, out: &mut impl Write) -> Result<(), String> {
        if pending.is_empty() {
            return Ok(());
        }
        for resp in self.run_batch(std::mem::take(pending)) {
            writeln!(out, "{}", resp.compact()).map_err(|e| format!("write response: {e}"))?;
        }
        out.flush().map_err(|e| format!("flush responses: {e}"))
    }

    /// Aggregate accounting so far. Latency percentiles come from the
    /// bounded sample buffer (exact below [`LATENCY_CAP`] requests,
    /// uniformly subsampled beyond).
    pub fn summary(&self) -> ServiceSummary {
        let mut lat = self.stats.latencies_us.lock().unwrap().samples.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                0.0
            } else {
                lat[(((lat.len() - 1) as f64) * p).round() as usize]
            }
        };
        let mean = if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 };
        // min extraction time over timed extractions only; cache hits
        // were Sample::Cached markers and never entered the floor
        let min_extract_us =
            self.stats.min_extract_s.lock().unwrap().map(|s| s * 1e6);
        ServiceSummary {
            requests: self.stats.requests.load(Ordering::Relaxed),
            errors: self.stats.errors.load(Ordering::Relaxed),
            batches: self.stats.batches.load(Ordering::Relaxed),
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            distinct_kernels: self.cache.len(),
            latency_p50_us: pct(0.50),
            latency_p99_us: pct(0.99),
            latency_mean_us: mean,
            min_extract_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::registry::builtins;
    use crate::perfmodel::Model;
    use crate::stats::extract;

    /// A store with hand-made (but valid) weights for one device — unit
    /// tests exercise resolution/caching/accounting without paying for
    /// a fit; end-to-end fidelity lives in `rust/tests/service.rs`.
    fn toy_service() -> Service {
        let schema = Schema::full();
        let mut weights = vec![0.0; schema.len()];
        // weight only the launch-overhead columns: prediction =
        // 2e-9 * workgroups + 5e-6
        weights[schema.len() - 2] = 2e-9;
        weights[schema.len() - 1] = 5e-6;
        let model = Model {
            device: "k40c".into(),
            weights,
            active: vec![schema.len() - 2, schema.len() - 1],
            train_rel_err_geomean: 0.1,
            solver: "native-cholesky",
        };
        let mut store = ModelStore::new(&schema, ExtractOpts::default());
        store.insert(StoredModel::new(model, 8e-6, 400, builtins().get("k40c").unwrap()));
        // single worker: the per-response `cache` field reflects actual
        // execution, and two identical requests racing on a cold cache
        // within one concurrent batch would otherwise flip which one
        // reports the miss (the predictions are identical either way) —
        // these unit tests assert exact hit/miss sequences
        let cfg = ServiceConfig { workers: 1, ..ServiceConfig::default() };
        Service::new(store, builtins().clone(), cfg).unwrap()
    }

    #[test]
    fn named_case_request_predicts_and_caches() {
        let svc = toy_service();
        let r1 = svc.respond(r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#);
        assert_eq!(r1.get_str("cache"), Some("miss"), "{r1}");
        assert_eq!(r1.get_str("case"), Some("a"));
        assert_eq!(r1.get("id"), Some(&Json::Num(1.0)));
        let pred = r1.get_f64("predicted_s").unwrap();
        assert!(pred > 0.0 && pred.is_finite());
        // same kernel structure again: a hit, same prediction
        let r2 = svc.respond(r#"{"id": 2, "device": "k40c", "kernel": "fd5", "case": "a"}"#);
        assert_eq!(r2.get_str("cache"), Some("hit"));
        assert_eq!(r2.get_f64("predicted_s"), Some(pred));
        // cross-check against a direct extraction + inner product
        let suite = kernels::eval_suite(builtins().get("k40c").unwrap());
        let case = suite
            .iter()
            .find(|c| c.label.starts_with("fd5/a/"))
            .unwrap();
        let props = extract(&case.kernel, &case.env, ExtractOpts::default()).unwrap();
        let v = props.eval(&Schema::full(), &case.env).unwrap();
        let expect = svc.store().get("k40c").unwrap().model.predict(&v);
        assert_eq!(pred, expect);
        let s = svc.summary();
        assert_eq!((s.requests, s.errors, s.cache_hits, s.cache_misses), (2, 0, 1, 1));
        assert!(s.min_extract_us.unwrap() > 0.0);
    }

    #[test]
    fn named_env_and_default_case() {
        let svc = toy_service();
        let r = svc.respond(r#"{"device": "k40c", "kernel": "fd5", "env": {"n": 4096}}"#);
        assert!(r.get("error").is_none(), "{r}");
        assert!(r.get("case").is_none(), "custom env has no case letter");
        // default case is `a`
        let r = svc.respond(r#"{"device": "k40c", "kernel": "fd5"}"#);
        assert_eq!(r.get_str("case"), Some("a"));
        // missing parameter is a per-request error, not a crash
        let r = svc.respond(r#"{"device": "k40c", "kernel": "mm_skinny", "env": {"n": 512}}"#);
        assert!(r.get_str("error").unwrap().contains("requires parameter"), "{r}");
    }

    #[test]
    fn error_responses_echo_id_and_count() {
        let svc = toy_service();
        let r = svc.respond(r#"{"id": "q7", "device": "k40c", "kernel": "nope"}"#);
        assert!(r.get_str("error").unwrap().contains("unknown kernel"), "{r}");
        assert_eq!(r.get_str("id"), Some("q7"));
        let r = svc.respond(r#"{"device": "quadro", "kernel": "fd5"}"#);
        assert!(r.get_str("error").unwrap().contains("unknown device"), "{r}");
        // device in registry but not in the store
        let r = svc.respond(r#"{"device": "titan_x", "kernel": "fd5"}"#);
        assert!(r.get_str("error").unwrap().contains("no fitted model"), "{r}");
        let r = svc.respond("garbage");
        assert!(r.get("error").is_some());
        assert_eq!(svc.summary().errors, 4);
    }

    #[test]
    fn batch_preserves_order_and_counts_batches() {
        let svc = toy_service();
        let lines: Vec<String> = (0..6)
            .map(|i| {
                let case = ["a", "b"][i % 2];
                format!(r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "{case}"}}"#)
            })
            .collect();
        let out = svc.run_batch(lines);
        assert_eq!(out.len(), 6);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.get_f64("id"), Some(i as f64), "{r}");
        }
        assert_eq!(svc.summary().batches, 1);
    }

    #[test]
    fn serve_loop_roundtrips_ldjson() {
        let svc = toy_service();
        let input = "\n".to_string()
            + r#"{"id": 1, "device": "k40c", "kernel": "nbody", "case": "a"}"#
            + "\n"
            + r#"{"id": 2, "device": "k40c", "kernel": "nbody", "case": "a"}"#
            + "\n";
        let mut out = Vec::new();
        let summary = svc.serve(input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r1.get_str("cache"), Some("miss"));
        assert_eq!(r2.get_str("cache"), Some("hit"));
        assert_eq!(r1.get_f64("predicted_s"), r2.get_f64("predicted_s"));
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.cache_hits, 1);
    }

    #[test]
    fn latency_buffer_stays_bounded_under_heavy_traffic() {
        let mut buf = LatencyBuf::default();
        for i in 0..10 * LATENCY_CAP {
            buf.push(i as f64);
        }
        assert!(buf.samples.len() < LATENCY_CAP, "held {}", buf.samples.len());
        assert!(buf.stride > 1, "decimation must have kicked in");
        assert_eq!(buf.seen, (10 * LATENCY_CAP) as u64);
        // below the cap, recording is exact
        let mut small = LatencyBuf::default();
        for i in 0..100 {
            small.push(i as f64);
        }
        assert_eq!(small.samples.len(), 100);
        // the service-side accessor reports the bounded count
        let svc = toy_service();
        svc.respond(r#"{"device": "k40c", "kernel": "fd5", "case": "a"}"#);
        assert_eq!(svc.latency_samples_held(), 1);
    }

    #[test]
    fn interactive_loop_answers_every_line_as_its_own_batch() {
        let svc = toy_service();
        let input = r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#.to_string()
            + "\n"
            + r#"{"id": 2, "device": "k40c", "kernel": "fd5", "case": "a"}"#
            + "\n";
        let mut out = Vec::new();
        let summary = svc.serve_interactive(input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 2);
        // each line was flushed as its own (size-1) batch — the
        // conversational guarantee a blocking TCP client relies on
        assert_eq!(summary.batches, 2);
        assert_eq!(summary.requests, 2);
    }

    #[test]
    fn oversized_inline_group_rejected_for_device() {
        // r9_fury caps groups at 256; a 512-lane inline kernel must be
        // rejected for it (after adding fury weights to the store)
        let schema = Schema::full();
        let mut weights = vec![0.0; schema.len()];
        weights[schema.len() - 1] = 1e-6;
        let model = Model {
            device: "r9_fury".into(),
            weights,
            active: vec![schema.len() - 1],
            train_rel_err_geomean: 0.1,
            solver: "native-cholesky",
        };
        let mut store = ModelStore::new(&schema, ExtractOpts::default());
        store.insert(StoredModel::new(model, 45e-6, 300, builtins().get("r9_fury").unwrap()));
        let svc =
            Service::new(store, builtins().clone(), ServiceConfig::default()).unwrap();
        let spec = r#"{"params": ["n"],
            "dims": [{"iname": "g0", "tag": "group0", "hi": "n", "tiles": 512},
                     {"iname": "l0", "tag": "local0", "hi": 512}],
            "arrays": [{"name": "o", "dtype": "f32", "shape": ["n"], "output": true}],
            "insns": [{"store": "o", "idx": ["512*g0 + l0"], "expr": {"lit": 1},
                       "within": ["g0", "l0"]}]}"#;
        let line = format!(r#"{{"device": "r9_fury", "lpir": {spec}, "env": {{"n": 8192}}}}"#);
        let r = svc.respond(&line);
        assert!(r.get_str("error").unwrap().contains("exceeds"), "{r}");
    }
}
