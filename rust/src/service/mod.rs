//! `service` — the batched, cached kernel-runtime prediction server.
//!
//! Since the engine refactor this module is deliberately thin: it owns
//! **request parsing** ([`request`], [`spec`]) and **response
//! rendering + accounting**, and delegates every resolution,
//! extraction, caching and weight decision to the shared
//! [`crate::engine::Engine`]:
//!
//! 1. **Artifacts** ([`store`]) — `fit --save models.json` persists one
//!    weight table per device, fingerprinted against the schema, the
//!    device profile and the capability-derived measurement suite;
//!    [`crate::engine::Engine::install_store`] refuses stale artifacts,
//!    and a [`crate::engine::Reloader`] can hot-swap a rewritten
//!    artifact between batches/connections (`serve --watch`).
//! 2. **Requests** ([`request`]) — line-delimited JSON: single-device
//!    predictions (named zoo kernel or inline `lpir` spec), batched
//!    device×kernel `matrix` requests (parsed once, predicted across
//!    every named device), and a `shutdown` drain command.
//! 3. **Caching** ([`cache`]) — symbolic extraction is the expensive
//!    step (milliseconds); results are shared through the engine's
//!    sharded, eviction-bounded cache keyed by the *structural* kernel
//!    hash ([`hash`]), so a warm request never re-runs extraction and
//!    drops straight onto the compiled [`crate::qpoly::tape::PwTape`]
//!    fast path (microseconds). With `--props-cache FILE` the cache is
//!    additionally layered over a persistent, append-only extraction
//!    log ([`diskcache`]): a restarted instance preloads its
//!    predecessor's extractions and warm-starts with zero misses, and
//!    an incompatible file (format/schema/options mismatch) is refused
//!    with a warning rather than trusted.
//! 4. **Batching** ([`Service::serve`]) — requests drain in
//!    deterministic batches onto [`crate::util::executor::par_map`];
//!    responses preserve input order, and per-request latency plus
//!    cache-hit/eviction accounting surface in a
//!    [`crate::report::render_service`] summary. Cache hits are
//!    excluded from the extraction-time floor entirely — a hit is a
//!    non-run, not a 0-second run (the exclusion rule
//!    [`crate::harness::Sample::Cached`] /
//!    [`crate::harness::Protocol::reduce_samples`] define and
//!    unit-test).
//! 5. **Hostile input** — request lines are length-capped
//!    ([`ServiceConfig::max_line`]): an oversized line is answered with
//!    an `{"error": ...}` (best-effort `id` echo from the retained
//!    prefix) instead of buffering without bound, and the stream then
//!    resumes at the next newline.
//! 6. **Degradation & health** (see `DESIGN.md` § Robustness) —
//!    per-request `deadline_ms` budgets answered with
//!    `"reason": "deadline"` errors once expired; a bounded pending
//!    queue ([`ServiceConfig::queue_cap`]) that sheds overload with
//!    `"reason": "overloaded"` + [`RETRY_AFTER_MS`]; degraded-mode
//!    fallback predictions surfaced with `"degraded": true` +
//!    `"served_by"`; and `{"cmd": "health"}` / `{"cmd": "stats"}`
//!    introspection (store fingerprint, reloader state,
//!    cache/quarantine/breaker counters, fault-injection tallies —
//!    driven end to end by `rust/tests/chaos.rs`).
//!
//! The TCP listener ([`tcp`]) serves each connection on its own thread
//! over one shared `Arc<Service>`; `{"cmd": "shutdown"}` drains it
//! deterministically.
//!
//! Property vectors are hardware-independent (the cross-machine result
//! of arXiv:1904.09538), so one cached extraction answers queries for
//! *every* registered device; only the weight table is per-device.

// A serving loop must degrade, never panic: every fallible path in this
// module tree answers with an `{"error": ...}` line instead of
// unwinding a worker thread (tests opt back in per-module).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod cache;
pub mod diskcache;
pub mod hash;
pub mod reactor;
pub mod request;
pub mod spec;
pub mod store;
pub mod tcp;

pub use cache::SharedPropsCache;
pub use request::{KernelRef, MatrixRequest, PredictRequest, Request};
pub use store::{ModelStore, StoredModel};

use crate::engine::{Config, Engine, MatrixPrediction, Prediction, Reloader};
use crate::gpusim::DeviceRegistry;
use crate::obs::log::Level;
use crate::obs::span::{self, Span};
use crate::obs::{Counter, Gauge, Histogram, Registry, Snapshot};
use crate::olog;
use crate::report::ServiceSummary;
use crate::stats::ExtractOpts;
use crate::util::executor::default_workers;
use crate::util::fault::FaultPlan;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Default request-line length cap (bytes). Far above any legitimate
/// inline kernel spec, far below what a hostile unterminated stream
/// could otherwise make one connection buffer.
pub const MAX_REQUEST_LINE: usize = 1 << 20;

/// Advisory client back-off (milliseconds) attached to every
/// `"reason": "overloaded"` shed response (bounded queue and TCP
/// connection guard alike).
pub const RETRY_AFTER_MS: u64 = 50;

/// Accept-failure log window: under SYN churn or fd exhaustion both
/// transports count every failed `accept` ([`ServiceSummary`]
/// `accept_errors`) but print at most one line per distinct errno per
/// window, with a suppressed-repeat count — diagnosis without flooding.
const ACCEPT_LOG_WINDOW: Duration = Duration::from_secs(5);

/// Mutex lock that survives a poisoned peer: accounting state stays
/// usable even if another worker thread panicked mid-update (a torn
/// counter beats a cascading panic in a serving loop).
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Serving configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// requests per batch handed to the executor (order-preserving)
    pub batch: usize,
    /// worker threads per batch
    pub workers: usize,
    /// extraction options (must match how the model was fitted)
    pub extract: ExtractOpts,
    /// request-line length cap in bytes ([`MAX_REQUEST_LINE`] default)
    pub max_line: usize,
    /// props-cache entry bound (see
    /// [`SharedPropsCache::with_capacity`])
    pub cache_capacity: usize,
    /// pending-request queue bound for the batched serving loop: lines
    /// beyond this many waiting requests are shed in stream order with
    /// an `{"error": ..., "reason": "overloaded", "retry_after_ms":
    /// ...}` response instead of queueing without bound
    pub queue_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            batch: 64,
            workers: default_workers(),
            extract: ExtractOpts::default(),
            max_line: MAX_REQUEST_LINE,
            cache_capacity: cache::DEFAULT_CAPACITY,
            queue_cap: 4096,
        }
    }
}

/// Span cap for one `{"cmd": "trace"}` response (the slow-root ring is
/// always included in full).
const TRACE_EXPORT_LIMIT: usize = 256;

/// Per-service accounting, held as pre-registered handles into the
/// service's own [`Registry`] (per-instance, not process-global, so
/// concurrent services — and parallel tests — never share counters).
/// Every update is one relaxed atomic op, the same cost as the ad-hoc
/// `AtomicU64`s and decimating sample buffers this replaced; the
/// histograms are bounded by construction (65 log₂ buckets) instead of
/// by decimation, so every observation counts and single-bucket
/// populations report exact percentiles.
struct Stats {
    registry: Registry,
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    batches: Arc<Counter>,
    /// per-request wall latency in µs (batch wall time, charged to
    /// every request answered in the batch)
    latency_us: Arc<Histogram>,
    /// formed-batch widths (requests per executor batch)
    batch_width: Arc<Histogram>,
    /// requests shed by the bounded pending queue or connection guard
    shed: Arc<Counter>,
    /// requests answered with a deadline error instead of a prediction
    deadline_expired: Arc<Counter>,
    /// predictions served by a degraded-mode fallback device
    degraded: Arc<Counter>,
    /// TCP connections dropped by the `conn.abort` fault site
    conn_aborted: Arc<Counter>,
    /// TCP connections delayed by the `conn.slow` fault site
    conn_slowed: Arc<Counter>,
    /// failed `accept` calls, both transports (each one is counted
    /// here; the log limiter below decides which get printed)
    accept_errors: Arc<Counter>,
    /// fd-exhaustion backoffs taken by the reactor's accept path
    accept_backoffs: Arc<Counter>,
    /// formation-queue depth, sampled by the reactor after each
    /// dispatch round (stays 0 under the threaded transport, whose
    /// queue lives per connection)
    queue_depth: Arc<Gauge>,
    /// exact running floor over every *timed* extraction. Cache hits
    /// contribute nothing — the 0-second-sample pollution that
    /// [`crate::harness::Sample::Cached`] /
    /// [`crate::harness::Protocol::reduce_samples`] define and
    /// unit-test the exclusion rule for — so this is bounded state
    /// with an exact answer, even for miss-heavy inline workloads.
    /// (Not a registry metric: it is a fractional-second min, not a
    /// counter/gauge/histogram.)
    min_extract_s: Mutex<Option<f64>>,
    /// per-errno accept-failure log limiter state
    accept_log: Mutex<BTreeMap<i32, AcceptLog>>,
}

impl Stats {
    /// Register every service metric up front: recording paths hold
    /// the returned handles (never the registry lock), and snapshots
    /// carry all names from the first request on.
    fn new() -> Stats {
        let registry = Registry::new();
        Stats {
            requests: registry.counter("requests_total"),
            errors: registry.counter("errors_total"),
            batches: registry.counter("batches_total"),
            latency_us: registry.histogram("request_latency_us"),
            batch_width: registry.histogram("batch_width"),
            shed: registry.counter("shed_total"),
            deadline_expired: registry.counter("deadline_expired_total"),
            degraded: registry.counter("degraded_total"),
            conn_aborted: registry.counter("conn_aborted_total"),
            conn_slowed: registry.counter("conn_slowed_total"),
            accept_errors: registry.counter("accept_errors_total"),
            accept_backoffs: registry.counter("accept_backoffs_total"),
            queue_depth: registry.gauge("queue_depth"),
            min_extract_s: Mutex::new(None),
            accept_log: Mutex::new(BTreeMap::new()),
            registry,
        }
    }
}

/// Log-limiter state for one accept-failure errno.
#[derive(Default)]
struct AcceptLog {
    last_logged: Option<Instant>,
    /// identical failures swallowed since the last printed line
    suppressed: u64,
}

/// The prediction server front end: request parsing + response
/// rendering + accounting over a shared [`Engine`] (which owns the
/// registry, the validated hot-swappable model store and the
/// eviction-bounded props cache).
pub struct Service {
    engine: Arc<Engine>,
    cfg: ServiceConfig,
    stats: Stats,
    /// set by a `{"cmd": "shutdown"}` request: serving loops stop
    /// reading after their current batch, and the TCP listener drains
    shutdown: AtomicBool,
    /// `serve --watch`: hot artifact reload, polled between batches
    /// and connections
    reload: Option<Reloader>,
}

impl Service {
    /// Build a service over a loaded artifact. The store is validated
    /// against `registry` (profile + suite + schema fingerprints and
    /// the extraction options) and installed into a fresh engine.
    pub fn new(
        store: ModelStore,
        registry: DeviceRegistry,
        cfg: ServiceConfig,
    ) -> Result<Service, String> {
        let engine = Engine::with_cache_capacity(
            Config { registry, extract: cfg.extract, workers: cfg.workers, ..Config::default() },
            cfg.cache_capacity,
        );
        engine.install_store(store)?;
        Service::over(Arc::new(engine), cfg)
    }

    /// Build a service front end over an existing engine (which must
    /// already have a store installed). Lets tests and embedders share
    /// one engine between the batch pipelines and the server.
    pub fn over(engine: Arc<Engine>, cfg: ServiceConfig) -> Result<Service, String> {
        if engine.store_snapshot().is_none() {
            return Err("no model artifact installed (run `fit --save`)".into());
        }
        if engine.config().extract != cfg.extract {
            return Err(format!(
                "engine extraction options {:?} do not match the service \
                 configuration {:?}",
                engine.config().extract,
                cfg.extract
            ));
        }
        Ok(Service {
            engine,
            cfg,
            stats: Stats::new(),
            shutdown: AtomicBool::new(false),
            reload: None,
        })
    }

    /// The shared engine behind this service.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Snapshot of the currently installed model store.
    pub fn store(&self) -> Arc<ModelStore> {
        match self.engine.store_snapshot() {
            Some(s) => s,
            // Service::over refuses engines without a store
            None => unreachable!("service construction requires a store"),
        }
    }

    pub fn cache(&self) -> &SharedPropsCache {
        self.engine.cache()
    }

    /// The fault plan threaded through the engine configuration
    /// (`None` when chaos injection is off).
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.engine.config().faults.clone()
    }

    /// TCP-layer accounting hooks ([`tcp`] owns the sockets, the
    /// service owns the counters the health surface reports).
    pub(crate) fn note_conn_aborted(&self) {
        self.stats.conn_aborted.inc();
    }

    pub(crate) fn note_conn_slowed(&self) {
        self.stats.conn_slowed.inc();
    }

    pub(crate) fn note_shed(&self) {
        self.stats.shed.inc();
    }

    /// Count one failed `accept`. Returns `Some(message)` when the
    /// caller should actually print it: at most one line per distinct
    /// errno per [`ACCEPT_LOG_WINDOW`], annotated with how many
    /// identical failures were suppressed since the last printed one.
    pub(crate) fn note_accept_error(&self, err: &std::io::Error) -> Option<String> {
        self.stats.accept_errors.inc();
        let errno = err.raw_os_error().unwrap_or(-1);
        let mut log = locked(&self.stats.accept_log);
        let state = log.entry(errno).or_default();
        let now = Instant::now();
        if let Some(last) = state.last_logged {
            if now.duration_since(last) < ACCEPT_LOG_WINDOW {
                state.suppressed += 1;
                return None;
            }
        }
        let suppressed = std::mem::take(&mut state.suppressed);
        state.last_logged = Some(now);
        Some(if suppressed == 0 {
            format!("accept failed: {err}")
        } else {
            format!("accept failed: {err} ({suppressed} identical failures suppressed)")
        })
    }

    /// Count one fd-exhaustion accept backoff (reactor transport).
    pub(crate) fn note_accept_backoff(&self) {
        self.stats.accept_backoffs.inc();
    }

    /// Record the formation-queue depth after a reactor dispatch round.
    pub(crate) fn note_queue_depth(&self, depth: usize) {
        self.stats.queue_depth.set(depth as u64);
    }

    /// The serving configuration this service was built with.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Watch `path` (the `--models` artifact) for rewrites: the serving
    /// loops re-stat it between batches and connections and atomically
    /// swap a validated new store in ([`Reloader`]). The current file
    /// state counts as already loaded. The engine's fault plan (if any)
    /// rides along so `reload.io` faults exercise this reloader.
    pub fn watch(&mut self, path: &Path) {
        self.reload = Some(
            Reloader::primed(path).with_faults(self.engine.config().faults.clone()),
        );
    }

    /// Has a `{"cmd": "shutdown"}` request asked the serving loops to
    /// drain?
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Poll the watched artifact now (no-op when not watching).
    /// `Some(Ok(true))` means a new store was swapped in.
    pub fn poll_reload(&self) -> Option<Result<bool, String>> {
        self.reload.as_ref().map(|r| r.maybe_reload(&self.engine))
    }

    /// Between-batches reload tick: poll and log, never fail the
    /// serving loop — a bad rewrite keeps the old store serving.
    pub(crate) fn reload_tick(&self) {
        match self.poll_reload() {
            Some(Ok(true)) => olog!(Level::Info, "uniperf serve: reloaded model artifact"),
            Some(Err(e)) => {
                olog!(
                    Level::Warn,
                    "uniperf serve: artifact reload failed (keeping current models): {e}"
                )
            }
            Some(Ok(false)) | None => {}
        }
    }

    /// Record a timed extraction into the running floor (cache hits
    /// pass `None` — the [`crate::harness::Sample::Cached`] rule).
    fn note_extract(&self, extract_s: Option<f64>) {
        if let Some(t) = extract_s {
            let mut m = locked(&self.stats.min_extract_s);
            *m = Some(m.map_or(t, |x| x.min(t)));
        }
    }

    /// `Some(response)` when the request's `deadline_ms` budget was
    /// already spent by the time it reached execution (time in the
    /// batch window counts; a zero budget always expires).
    fn deadline_response(
        &self,
        deadline_ms: Option<f64>,
        enqueued: Instant,
        id: Option<&Json>,
    ) -> Option<Json> {
        let budget = deadline_ms?;
        let waited = enqueued.elapsed().as_secs_f64() * 1e3;
        if waited <= budget {
            return None;
        }
        self.stats.errors.inc();
        self.stats.deadline_expired.inc();
        let mut pairs = vec![
            (
                "error",
                Json::Str(format!(
                    "deadline exceeded: waited {waited:.3} ms against a {budget} ms budget"
                )),
            ),
            ("reason", Json::Str("deadline".into())),
        ];
        if let Some(id) = id {
            pairs.push(("id", id.clone()));
        }
        Some(Json::obj(pairs))
    }

    /// Handle one request line: parse, delegate to the engine, account,
    /// and render the response object. Never panics on malformed input —
    /// errors come back as `{"error": ...}` responses (echoing `id` when
    /// it parsed).
    pub fn respond(&self, line: &str) -> Json {
        self.respond_at(line, Instant::now())
    }

    /// [`Service::respond`] with an explicit enqueue time: `deadline_ms`
    /// budgets are measured from when the server first read the line,
    /// so time spent waiting in a batch window counts against them.
    fn respond_at(&self, line: &str, enqueued: Instant) -> Json {
        match self.answer_batch(vec![(line.to_string(), enqueued)], 1).pop() {
            Some(resp) => resp,
            // answer_batch renders one response per line it was given
            None => unreachable!("one response per request line"),
        }
    }

    /// Answer one *formed batch* of request lines, in order. This is
    /// the single rendering path for every transport: introspection,
    /// control and error responses are answered inline, and all live
    /// predictions coalesce into one [`Engine::predict_batch`] call so
    /// the SoA tape evaluator sees the whole cross-request batch at
    /// once (PR 7 pinned batch-vs-scalar bit identity, so the rendered
    /// bytes match the scalar path exactly). `workers` bounds the
    /// resolution fan-out inside the engine call — a caller that
    /// already parallelizes across batches (the reactor's worker pool)
    /// passes 1.
    pub fn respond_batch(&self, lines: Vec<(String, Instant)>, workers: usize) -> Vec<Json> {
        if lines.is_empty() {
            return Vec::new();
        }
        self.stats.batches.inc();
        self.stats.batch_width.observe(lines.len() as u64);
        self.answer_batch(lines, workers)
    }

    /// [`Service::respond_batch`] without the batch accounting (the
    /// single-request [`Service::respond`] path is not a batch).
    fn answer_batch(&self, lines: Vec<(String, Instant)>, workers: usize) -> Vec<Json> {
        if lines.is_empty() {
            return Vec::new();
        }
        let t0 = Instant::now();
        // span tree per batch: one `svc.request` child per line (meta =
        // how it was answered — the conservation unit, and the only
        // per-request span so warm traffic pays for a single record),
        // then the shared evaluator and renderer get one child each.
        // Inert and free when tracing is off. A child (not root) so the
        // reactor's `reactor.dispatch` span adopts it; standalone it
        // roots a fresh trace.
        let mut batch_span = Span::child("svc.batch");
        if span::enabled() {
            batch_span.set_meta(format!("width={}", lines.len()));
        }
        // first pass: parse and answer everything that never reaches
        // the evaluator; live predictions collect into one batch
        let mut preds: Vec<PredictRequest> = Vec::new();
        let mut pred_ids: Vec<Option<Json>> = Vec::new();
        let mut slots: Vec<Option<Json>> = Vec::with_capacity(lines.len());
        for (line, enqueued) in &lines {
            self.stats.requests.inc();
            let mut req_span = Span::child("svc.request");
            let (resp, kind) = match Request::parse(line) {
                Err(e) => {
                    // salvage the id for correlation even when the
                    // request is otherwise malformed (documented
                    // id-echo contract)
                    let id = Json::parse(line).ok().and_then(|j| j.get("id").cloned());
                    (Some(self.error_response(id.as_ref(), e)), "error")
                }
                Ok(Request::Shutdown { id }) => (Some(self.shutdown_response(id)), "shutdown"),
                Ok(Request::Health { id }) => (Some(self.health_response(id)), "health"),
                Ok(Request::Stats { id }) => (Some(self.stats_response(id)), "stats"),
                Ok(Request::Metrics { id }) => (Some(self.metrics_response(id)), "metrics"),
                Ok(Request::Trace { id }) => (Some(self.trace_response(id)), "trace"),
                Ok(Request::Matrix(req)) => {
                    match self.deadline_response(req.deadline_ms, *enqueued, req.id.as_ref()) {
                        Some(expired) => (Some(expired), "deadline"),
                        None => match self.engine.predict_matrix(&req) {
                            Err(e) => (Some(self.error_response(req.id.as_ref(), e)), "error"),
                            Ok(mp) => (Some(self.render_matrix(mp)), "matrix"),
                        },
                    }
                }
                Ok(Request::Predict(req)) => {
                    match self.deadline_response(req.deadline_ms, *enqueued, req.id.as_ref()) {
                        Some(expired) => (Some(expired), "deadline"),
                        None => {
                            pred_ids.push(req.id.clone());
                            preds.push(req);
                            (None, "predict")
                        }
                    }
                }
            };
            req_span.set_meta(kind);
            drop(req_span);
            slots.push(resp);
        }
        // one batched engine call answers every live prediction
        let outcomes = {
            let _e = Span::child("svc.eval");
            self.engine.predict_batch(preds, workers)
        };
        let _r = Span::child("svc.render");
        let mut outcomes = outcomes.into_iter().zip(pred_ids);
        let out: Vec<Json> = slots
            .into_iter()
            .map(|slot| match slot {
                Some(resp) => resp,
                None => match outcomes.next() {
                    Some((Ok(p), _)) => self.render_prediction(p),
                    Some((Err(e), id)) => self.error_response(id.as_ref(), e),
                    // predict_batch answers every request it was given
                    None => unreachable!("one outcome per batched prediction"),
                },
            })
            .collect();
        drop(_r);
        let dt_us = t0.elapsed().as_secs_f64() * 1e6;
        for _ in 0..out.len() {
            self.stats.latency_us.observe_f64(dt_us);
        }
        out
    }

    /// Render + count a request-level error (`{"error": ...}` with the
    /// id echoed when known).
    fn error_response(&self, id: Option<&Json>, msg: String) -> Json {
        self.stats.errors.inc();
        let mut pairs = vec![("error", Json::Str(msg))];
        if let Some(id) = id {
            pairs.push(("id", id.clone()));
        }
        Json::obj(pairs)
    }

    fn shutdown_response(&self, id: Option<Json>) -> Json {
        // flag first: the loop that flushes this response stops
        // reading right after
        self.shutdown.store(true, Ordering::SeqCst);
        let mut pairs = vec![("ok", Json::Str("shutdown".into()))];
        if let Some(id) = id {
            pairs.push(("id", id));
        }
        Json::obj(pairs)
    }

    fn stats_response(&self, id: Option<Json>) -> Json {
        let mut pairs = vec![
            ("ok", Json::Str("stats".into())),
            ("summary", self.summary().to_json()),
        ];
        if let Some(id) = id {
            pairs.push(("id", id));
        }
        Json::obj(pairs)
    }

    /// Render one successful prediction (shared by the single and
    /// matrix paths' accounting: extraction floor + degraded counter).
    fn render_prediction(&self, p: Prediction) -> Json {
        self.note_extract(p.extract_s);
        let mut pairs = vec![
            ("device", Json::Str(p.device)),
            ("kernel", Json::Str(p.kernel)),
            ("predicted_s", Json::Num(p.predicted_s)),
            (
                "cache",
                Json::Str(if p.cache_hit { "hit".into() } else { "miss".into() }),
            ),
        ];
        if p.degraded {
            self.stats.degraded.inc();
            pairs.push(("degraded", Json::Bool(true)));
        }
        if let Some(sb) = p.served_by {
            pairs.push(("served_by", Json::Str(sb)));
        }
        if let Some(c) = p.case {
            pairs.push(("case", Json::Str(c)));
        }
        if let Some(id) = p.id {
            pairs.push(("id", id));
        }
        Json::obj(pairs)
    }

    fn render_matrix(&self, mp: MatrixPrediction) -> Json {
        let results = mp
            .per_device
            .into_iter()
            .map(|(device, outcome)| match outcome {
                Ok(p) => {
                    self.note_extract(p.extract_s);
                    let mut cell = vec![
                        ("device", Json::Str(device)),
                        ("predicted_s", Json::Num(p.predicted_s)),
                        (
                            "cache",
                            Json::Str(if p.cache_hit { "hit".into() } else { "miss".into() }),
                        ),
                    ];
                    if p.degraded {
                        self.stats.degraded.inc();
                        cell.push(("degraded", Json::Bool(true)));
                    }
                    if let Some(sb) = p.served_by {
                        cell.push(("served_by", Json::Str(sb)));
                    }
                    Json::obj(cell)
                }
                Err(e) => Json::obj(vec![
                    ("device", Json::Str(device)),
                    ("error", Json::Str(e)),
                ]),
            })
            .collect();
        let mut pairs = vec![
            ("kernel", Json::Str(mp.kernel)),
            ("results", Json::Arr(results)),
        ];
        if let Some(c) = mp.case {
            pairs.push(("case", Json::Str(c)));
        }
        if let Some(id) = mp.id {
            pairs.push(("id", id));
        }
        Json::obj(pairs)
    }

    /// The **one** metrics snapshot every introspection surface is
    /// built from: the service registry (request/error/shed counters,
    /// latency and batch-width histograms, queue depth) plus the
    /// engine-owned components folded in as synthetic entries (cache,
    /// quarantine, breakers, fault-site tallies) and the configured
    /// queue bound. `{"cmd": "health"}`, `{"cmd": "stats"}` /
    /// [`Service::summary`] and `{"cmd": "metrics"}` all read this —
    /// the three surfaces cannot drift apart.
    pub fn metrics_snapshot(&self) -> Snapshot {
        let mut snap = self.stats.registry.snapshot();
        let cache = self.engine.cache();
        snap.set_counter("cache_hits_total", cache.hits());
        snap.set_counter("cache_misses_total", cache.misses());
        snap.set_counter("cache_disk_hits_total", cache.disk_hits());
        snap.set_counter("cache_evictions_total", cache.evictions());
        snap.set_gauge("cache_entries", cache.len() as u64);
        snap.set_gauge("cache_capacity", cache.capacity() as u64);
        snap.set_counter("quarantined_total", self.engine.quarantined_total());
        snap.set_gauge("breakers_open", self.engine.breaker_open_count() as u64);
        snap.set_counter("breaker_trips_total", self.engine.breaker_trips());
        snap.set_gauge("queue_cap", self.cfg.queue_cap as u64);
        if let Some(plan) = self.engine.config().faults.as_ref() {
            // per-site fault tallies, names flattened to metric idiom
            // ("conn.abort" -> fault_conn_abort_attempts_total)
            if let Json::Obj(sites) = plan.counters_json() {
                for (site, v) in &sites {
                    if let Json::Obj(_) = v {
                        let base = format!("fault_{}", site.replace('.', "_"));
                        snap.set_counter(
                            &format!("{base}_attempts_total"),
                            v.get_f64("attempts").unwrap_or(0.0) as u64,
                        );
                        snap.set_counter(
                            &format!("{base}_injected_total"),
                            v.get_f64("injected").unwrap_or(0.0) as u64,
                        );
                    }
                }
            }
        }
        // campaign-plane counters (per-device cases measured, meas-cache
        // hit/miss/refusal): non-empty only when this process ran a
        // measurement campaign — a pure serving process never registers
        // them, so its exposition bytes are unchanged.
        snap.merge(&crate::obs::metrics::campaign().snapshot());
        snap
    }

    /// The `{"cmd": "metrics"}` surface: the unified snapshot as
    /// Prometheus-style exposition text.
    fn metrics_response(&self, id: Option<Json>) -> Json {
        let mut pairs = vec![
            ("ok", Json::Str("metrics".into())),
            ("exposition", Json::Str(self.metrics_snapshot().render_prometheus())),
        ];
        if let Some(id) = id {
            pairs.push(("id", id));
        }
        Json::obj(pairs)
    }

    /// The `{"cmd": "trace"}` surface: recorder state plus recent and
    /// slow spans (empty unless the process enabled tracing via
    /// `--trace`/`--profile`).
    fn trace_response(&self, id: Option<Json>) -> Json {
        let mut j = span::trace_json(TRACE_EXPORT_LIMIT);
        if let Json::Obj(m) = &mut j {
            m.insert("ok".into(), Json::Str("trace".into()));
            if let Some(id) = id {
                m.insert("id".into(), id);
            }
        }
        j
    }

    /// The `{"cmd": "health"}` surface: component status without
    /// touching the prediction path (safe to poll under load). Shape
    /// documented in `DESIGN.md` § Robustness. Every number is read
    /// from the unified [`Service::metrics_snapshot`], so health can
    /// never disagree with the summary or the metrics exposition.
    fn health_response(&self, id: Option<Json>) -> Json {
        let store = self.store();
        let snap = self.metrics_snapshot();
        let widths = snap.histogram("batch_width");
        let counter = |name: &str| Json::Num(snap.counter(name) as f64);
        let gauge = |name: &str| Json::Num(snap.gauge(name) as f64);
        let mut pairs = vec![
            ("ok", Json::Str("health".into())),
            (
                "store",
                Json::obj(vec![
                    ("fingerprint", Json::Str(store.fingerprint())),
                    (
                        "devices",
                        Json::Arr(store.devices().into_iter().map(Json::Str).collect()),
                    ),
                ]),
            ),
            (
                "reloader",
                Json::obj(vec![
                    ("watching", Json::Bool(self.reload.is_some())),
                    (
                        "last_error",
                        match self.reload.as_ref().and_then(|r| r.last_error()) {
                            Some(e) => Json::Str(e),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            (
                "cache",
                Json::obj(vec![
                    ("hits", counter("cache_hits_total")),
                    ("misses", counter("cache_misses_total")),
                    ("evictions", counter("cache_evictions_total")),
                    ("entries", gauge("cache_entries")),
                    ("capacity", gauge("cache_capacity")),
                ]),
            ),
            ("quarantined", counter("quarantined_total")),
            (
                "breakers",
                Json::obj(vec![
                    ("open", gauge("breakers_open")),
                    ("trips", counter("breaker_trips_total")),
                ]),
            ),
            (
                "counters",
                Json::obj(vec![
                    ("shed", counter("shed_total")),
                    ("deadline_expired", counter("deadline_expired_total")),
                    ("degraded", counter("degraded_total")),
                    ("conn_aborted", counter("conn_aborted_total")),
                    ("conn_slowed", counter("conn_slowed_total")),
                    ("accept_errors", counter("accept_errors_total")),
                    ("accept_backoffs", counter("accept_backoffs_total")),
                ]),
            ),
            (
                "queue",
                Json::obj(vec![
                    ("depth", gauge("queue_depth")),
                    ("cap", gauge("queue_cap")),
                ]),
            ),
            (
                "batch",
                Json::obj(vec![
                    ("width_p50", Json::Num(widths.quantile(0.50))),
                    ("width_p99", Json::Num(widths.quantile(0.99))),
                    ("width_mean", Json::Num(widths.mean())),
                ]),
            ),
            (
                "faults",
                match self.engine.config().faults.as_ref() {
                    Some(plan) => plan.counters_json(),
                    None => Json::Null,
                },
            ),
        ];
        if let Some(id) = id {
            pairs.push(("id", id));
        }
        Json::obj(pairs)
    }

    #[cfg(test)]
    fn latency_samples_held(&self) -> usize {
        self.stats.latency_us.snapshot().count() as usize
    }

    /// Handle one deterministic batch: responses come back in request
    /// order regardless of which worker answered which request.
    pub fn run_batch(&self, lines: Vec<String>) -> Vec<Json> {
        let now = Instant::now();
        self.run_batch_at(lines.into_iter().map(|l| (l, now)).collect())
    }

    /// [`Service::run_batch`] with per-line enqueue times (the batched
    /// serving loop records when each line was read, so `deadline_ms`
    /// budgets cover the wait in the batch window).
    fn run_batch_at(&self, lines: Vec<(String, Instant)>) -> Vec<Json> {
        self.respond_batch(lines, self.cfg.workers)
    }

    /// The piped serving loop (stdin, `--requests` files): read request
    /// lines, drain them in batches of `cfg.batch`, write one response
    /// line per request in order. Returns the run's summary at end of
    /// stream. Batching trades latency for throughput, so this loop is
    /// for EOF-bounded streams; a conversational peer that waits for
    /// each answer before sending more must use
    /// [`Service::serve_interactive`].
    pub fn serve<R: BufRead, W: Write>(
        &self,
        reader: R,
        mut out: W,
    ) -> Result<ServiceSummary, String> {
        self.serve_batched(reader, &mut out, self.cfg.batch)?;
        Ok(self.summary())
    }

    /// The conversational serving loop (TCP connections): every request
    /// line is answered and flushed before the next read, so a client
    /// that blocks on the response never deadlocks against the batch
    /// window. Each request is still accounted as a (size-1) batch.
    pub fn serve_interactive<R: BufRead, W: Write>(
        &self,
        reader: R,
        mut out: W,
    ) -> Result<ServiceSummary, String> {
        self.serve_batched(reader, &mut out, 1)?;
        Ok(self.summary())
    }

    /// One TCP connection's serving loop (conversational, no summary —
    /// the threaded listener prints one summary when it drains).
    pub(crate) fn serve_connection<R: BufRead, W: Write>(
        &self,
        reader: R,
        mut out: W,
    ) -> Result<(), String> {
        self.serve_batched(reader, &mut out, 1)
    }

    fn serve_batched<R: BufRead>(
        &self,
        mut reader: R,
        out: &mut impl Write,
        batch: usize,
    ) -> Result<(), String> {
        let mut pending: Vec<Pending> = Vec::new();
        let interrupted = || self.shutdown_requested();
        loop {
            match read_request_line(&mut reader, self.cfg.max_line, &interrupted)? {
                ReadLine::Eof => break,
                ReadLine::Line(line) => {
                    if line.trim().is_empty() {
                        continue;
                    }
                    if pending.len() >= self.cfg.queue_cap.max(1) {
                        // shed: answered at the next flush, in stream
                        // order, with a bounded error instead of
                        // queueing without bound
                        self.stats.requests.inc();
                        self.stats.errors.inc();
                        self.stats.shed.inc();
                        let id =
                            Json::parse(&line).ok().and_then(|j| j.get("id").cloned());
                        pending.push(Pending::Shed(id));
                        continue;
                    }
                    pending.push(Pending::Line(line, Instant::now()));
                    if pending.len() >= batch.max(1) {
                        self.reload_tick();
                        self.flush(&mut pending, out)?;
                        if self.shutdown_requested() {
                            return Ok(());
                        }
                    }
                }
                ReadLine::Oversized { id } => {
                    // answer in stream order: everything read before the
                    // oversized line first, then its bounded error
                    self.flush(&mut pending, out)?;
                    self.stats.requests.inc();
                    self.stats.errors.inc();
                    writeln!(out, "{}", self.oversized_error(id).compact())
                        .map_err(|e| format!("write response: {e}"))?;
                    out.flush().map_err(|e| format!("flush responses: {e}"))?;
                }
            }
        }
        self.reload_tick();
        self.flush(&mut pending, out)
    }

    fn flush(&self, pending: &mut Vec<Pending>, out: &mut impl Write) -> Result<(), String> {
        if pending.is_empty() {
            return Ok(());
        }
        // split the queue while preserving stream positions: live lines
        // go through the batch executor, shed slots render their
        // overload error in place
        let mut lines: Vec<(String, Instant)> = Vec::new();
        let mut slots: Vec<Option<Json>> = Vec::with_capacity(pending.len());
        for p in std::mem::take(pending) {
            match p {
                Pending::Line(l, t) => {
                    lines.push((l, t));
                    slots.push(None);
                }
                Pending::Shed(id) => slots.push(Some(self.shed_response(id))),
            }
        }
        let mut answers = self.run_batch_at(lines).into_iter();
        for slot in slots {
            let resp = match slot {
                Some(shed) => shed,
                None => match answers.next() {
                    Some(r) => r,
                    // run_batch_at answers every line it was given
                    None => unreachable!("one response per queued request"),
                },
            };
            writeln!(out, "{}", resp.compact()).map_err(|e| format!("write response: {e}"))?;
        }
        out.flush().map_err(|e| format!("flush responses: {e}"))
    }

    /// The bounded cap-exceeded error for one oversized request line
    /// (id already salvaged from the retained prefix). Counting is the
    /// caller's job — the two framing layers detect oversize at
    /// different points in their read loops.
    fn oversized_error(&self, id: Option<Json>) -> Json {
        let mut sp = Span::root("svc.request");
        sp.set_meta("oversized");
        let mut pairs = vec![(
            "error",
            Json::Str(format!("request line exceeds the {} byte cap", self.cfg.max_line)),
        )];
        if let Some(id) = id {
            pairs.push(("id", id));
        }
        Json::obj(pairs)
    }

    /// Reactor framing hook: count + render the oversized-line error,
    /// salvaging the id from the retained prefix.
    pub(crate) fn oversized_line(&self, prefix: &[u8]) -> Json {
        self.stats.requests.inc();
        self.stats.errors.inc();
        self.oversized_error(salvage_id(prefix))
    }

    /// Reactor backpressure hook: count + render the shed response for
    /// one request line dropped by the bounded global queue or a
    /// connection's write-buffer cap (same response either way — the
    /// client's remedy is identical: back off and retry).
    pub(crate) fn shed_line(&self, line: &str) -> Json {
        self.stats.requests.inc();
        self.stats.errors.inc();
        self.stats.shed.inc();
        let id = Json::parse(line).ok().and_then(|j| j.get("id").cloned());
        self.shed_response(id)
    }

    /// The connection-count guard response both TCP transports answer
    /// (and then close) with above `max_connections` concurrent
    /// connections. Counts the shed.
    pub(crate) fn conn_guard_response(&self, max_connections: usize) -> Json {
        self.note_shed();
        Json::obj(vec![
            (
                "error",
                Json::Str(format!(
                    "overloaded: server at capacity ({max_connections} concurrent \
                     connections)"
                )),
            ),
            ("reason", Json::Str("overloaded".into())),
            ("retry_after_ms", Json::Num(RETRY_AFTER_MS as f64)),
        ])
    }

    /// The bounded-queue shed response: the `"reason": "overloaded"` +
    /// `retry_after_ms` contract chaos tests pin.
    fn shed_response(&self, id: Option<Json>) -> Json {
        let mut sp = Span::root("svc.request");
        sp.set_meta("shed");
        let mut pairs = vec![
            (
                "error",
                Json::Str(format!(
                    "overloaded: the pending-request queue is full ({} waiting)",
                    self.cfg.queue_cap
                )),
            ),
            ("reason", Json::Str("overloaded".into())),
            ("retry_after_ms", Json::Num(RETRY_AFTER_MS as f64)),
        ];
        if let Some(id) = id {
            pairs.push(("id", id));
        }
        Json::obj(pairs)
    }

    /// Aggregate accounting so far, read off the unified
    /// [`Service::metrics_snapshot`]. Latency and formed-batch-width
    /// percentiles come from the bounded log₂ histograms (every
    /// observation counted; quantiles exact within their bucket).
    pub fn summary(&self) -> ServiceSummary {
        let snap = self.metrics_snapshot();
        let lat = snap.histogram("request_latency_us");
        let widths = snap.histogram("batch_width");
        // min extraction time over timed extractions only; cache hits
        // were Sample::Cached markers and never entered the floor
        let min_extract_us = locked(&self.stats.min_extract_s).map(|s| s * 1e6);
        ServiceSummary {
            requests: snap.counter("requests_total"),
            errors: snap.counter("errors_total"),
            batches: snap.counter("batches_total"),
            cache_hits: snap.counter("cache_hits_total"),
            cache_misses: snap.counter("cache_misses_total"),
            cache_evictions: snap.counter("cache_evictions_total"),
            distinct_kernels: snap.gauge("cache_entries") as usize,
            latency_p50_us: lat.quantile(0.50),
            latency_p90_us: lat.quantile(0.90),
            latency_p99_us: lat.quantile(0.99),
            latency_mean_us: lat.mean(),
            min_extract_us,
            shed: snap.counter("shed_total"),
            deadline_expired: snap.counter("deadline_expired_total"),
            degraded_served: snap.counter("degraded_total"),
            conn_aborted: snap.counter("conn_aborted_total"),
            conn_slowed: snap.counter("conn_slowed_total"),
            quarantined: snap.counter("quarantined_total"),
            accept_errors: snap.counter("accept_errors_total"),
            accept_backoffs: snap.counter("accept_backoffs_total"),
            queue_depth: snap.gauge("queue_depth"),
            batch_p50: widths.quantile(0.50),
            batch_p99: widths.quantile(0.99),
            batch_mean: widths.mean(),
        }
    }
}

/// One queued slot of the batched serving loop: a request waiting to
/// execute (with its enqueue time, for deadline budgets) or a request
/// already shed by the queue bound (answered at flush, in stream
/// order).
enum Pending {
    Line(String, Instant),
    Shed(Option<Json>),
}

/// Outcome of one capped line read.
enum ReadLine {
    Eof,
    Line(String),
    /// the line blew the cap; only a prefix was retained (for the
    /// best-effort `id` echo) and the rest was discarded to the newline
    Oversized { id: Option<Json> },
}

/// Read one `\n`-terminated line, buffering at most `cap` bytes. An
/// overlong line is consumed (without buffering) up to its newline so
/// the stream stays line-synchronized.
///
/// Timeout-shaped read errors (`WouldBlock`/`TimedOut` — TCP
/// connections carry a read timeout precisely for this) are not
/// errors: they re-check `interrupted` and keep waiting, so a reader
/// blocked on an idle socket observes a shutdown within one timeout
/// tick instead of pinning its connection thread forever. An
/// interrupted wait reads as end-of-stream.
fn read_request_line<R: BufRead>(
    r: &mut R,
    cap: usize,
    interrupted: &dyn Fn() -> bool,
) -> Result<ReadLine, String> {
    let mut buf: Vec<u8> = Vec::new();
    let mut oversized = false;
    loop {
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            Err(e) => match e.kind() {
                std::io::ErrorKind::Interrupted => continue,
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                    if interrupted() {
                        return Ok(ReadLine::Eof);
                    }
                    continue;
                }
                _ => return Err(format!("read request stream: {e}")),
            },
        };
        if chunk.is_empty() {
            // EOF
            if buf.is_empty() && !oversized {
                return Ok(ReadLine::Eof);
            }
            break;
        }
        let (take, found_newline) = match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => (pos, true),
            None => (chunk.len(), false),
        };
        if !oversized {
            if buf.len() + take > cap {
                oversized = true;
                let keep = cap - buf.len();
                buf.extend_from_slice(&chunk[..keep]);
            } else {
                buf.extend_from_slice(&chunk[..take]);
            }
        }
        let consumed = if found_newline { take + 1 } else { take };
        r.consume(consumed);
        if found_newline {
            break;
        }
    }
    if oversized {
        return Ok(ReadLine::Oversized { id: salvage_id(&buf) });
    }
    match String::from_utf8(buf) {
        Ok(s) => Ok(ReadLine::Line(s)),
        Err(_) => Err("read request stream: request line is not valid UTF-8".into()),
    }
}

/// Shared fixtures for the in-crate serving tests (`service`, `tcp`,
/// `engine`): hand-made — but registry-valid — stores that exercise
/// resolution, caching and accounting without paying for a fit.
#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub(crate) mod testutil {
    use super::{ModelStore, StoredModel};
    use crate::gpusim::registry::builtins;
    use crate::perfmodel::Model;
    use crate::stats::{ExtractOpts, Schema};

    /// A store weighting only the work-group and constant columns:
    /// prediction = `group_w · workgroups + const_w` per device.
    pub(crate) fn toy_store(devices: &[(&str, f64, f64)]) -> ModelStore {
        let schema = Schema::full();
        let mut store = ModelStore::new(&schema, ExtractOpts::default());
        for (device, group_w, const_w) in devices {
            let mut weights = vec![0.0; schema.len()];
            weights[schema.len() - 2] = *group_w;
            weights[schema.len() - 1] = *const_w;
            let model = Model {
                device: (*device).into(),
                weights,
                active: vec![schema.len() - 2, schema.len() - 1],
                train_rel_err_geomean: 0.1,
                solver: "native-cholesky",
            };
            store.insert(StoredModel::new(
                model,
                8e-6,
                400,
                builtins().get(device).unwrap(),
            ));
        }
        store
    }
}

/// Best-effort `id` recovery from the retained prefix of an oversized
/// line: find the first `"id"` key and parse the simple scalar after
/// it. Correlation-grade only — a quoted string containing `"id"`
/// earlier in the line can defeat it, which costs nothing but the echo.
fn salvage_id(prefix: &[u8]) -> Option<Json> {
    let text = String::from_utf8_lossy(prefix);
    let bytes = text.as_bytes();
    let mut i = text.find("\"id\"")? + 4;
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    if i >= bytes.len() || bytes[i] != b':' {
        return None;
    }
    i += 1;
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    if i >= bytes.len() {
        return None;
    }
    if bytes[i] == b'"' {
        let start = i + 1;
        let end = text[start..].find('"')? + start;
        return Some(Json::Str(text[start..end].to_string()));
    }
    let start = i;
    let mut j = i;
    while j < bytes.len()
        && (bytes[j].is_ascii_digit() || matches!(bytes[j], b'-' | b'+' | b'.' | b'e' | b'E'))
    {
        j += 1;
    }
    if j == start {
        return None;
    }
    text[start..j].parse::<f64>().ok().map(Json::Num)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::testutil::toy_store;
    use super::*;
    use crate::gpusim::registry::builtins;
    use crate::kernels;
    use crate::stats::{extract, Schema};

    fn toy_service() -> Service {
        // single worker: the per-response `cache` field reflects actual
        // execution, and two identical requests racing on a cold cache
        // within one concurrent batch would otherwise flip which one
        // reports the miss (the predictions are identical either way) —
        // these unit tests assert exact hit/miss sequences
        let cfg = ServiceConfig { workers: 1, ..ServiceConfig::default() };
        Service::new(
            toy_store(&[("k40c", 2e-9, 5e-6)]),
            builtins().clone(),
            cfg,
        )
        .unwrap()
    }

    #[test]
    fn named_case_request_predicts_and_caches() {
        let svc = toy_service();
        let r1 = svc.respond(r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#);
        assert_eq!(r1.get_str("cache"), Some("miss"), "{r1}");
        assert_eq!(r1.get_str("case"), Some("a"));
        assert_eq!(r1.get("id"), Some(&Json::Num(1.0)));
        let pred = r1.get_f64("predicted_s").unwrap();
        assert!(pred > 0.0 && pred.is_finite());
        // same kernel structure again: a hit, same prediction
        let r2 = svc.respond(r#"{"id": 2, "device": "k40c", "kernel": "fd5", "case": "a"}"#);
        assert_eq!(r2.get_str("cache"), Some("hit"));
        assert_eq!(r2.get_f64("predicted_s"), Some(pred));
        // cross-check against a direct extraction + inner product
        let suite = kernels::eval_suite(builtins().get("k40c").unwrap());
        let case = suite
            .iter()
            .find(|c| c.label.starts_with("fd5/a/"))
            .unwrap();
        let props = extract(&case.kernel, &case.env, ExtractOpts::default()).unwrap();
        let v = props.eval(&Schema::full(), &case.env).unwrap();
        let store = svc.store();
        let expect = store.get("k40c").unwrap().model.predict(&v);
        assert_eq!(pred, expect);
        let s = svc.summary();
        assert_eq!((s.requests, s.errors, s.cache_hits, s.cache_misses), (2, 0, 1, 1));
        assert!(s.min_extract_us.unwrap() > 0.0);
    }

    #[test]
    fn named_env_and_default_case() {
        let svc = toy_service();
        let r = svc.respond(r#"{"device": "k40c", "kernel": "fd5", "env": {"n": 4096}}"#);
        assert!(r.get("error").is_none(), "{r}");
        assert!(r.get("case").is_none(), "custom env has no case letter");
        // default case is `a`
        let r = svc.respond(r#"{"device": "k40c", "kernel": "fd5"}"#);
        assert_eq!(r.get_str("case"), Some("a"));
        // missing parameter is a per-request error, not a crash
        let r = svc.respond(r#"{"device": "k40c", "kernel": "mm_skinny", "env": {"n": 512}}"#);
        assert!(r.get_str("error").unwrap().contains("requires parameter"), "{r}");
    }

    #[test]
    fn error_responses_echo_id_and_count() {
        let svc = toy_service();
        let r = svc.respond(r#"{"id": "q7", "device": "k40c", "kernel": "nope"}"#);
        assert!(r.get_str("error").unwrap().contains("unknown kernel"), "{r}");
        assert_eq!(r.get_str("id"), Some("q7"));
        let r = svc.respond(r#"{"device": "quadro", "kernel": "fd5"}"#);
        assert!(r.get_str("error").unwrap().contains("unknown device"), "{r}");
        // device in registry but not in the store
        let r = svc.respond(r#"{"device": "titan_x", "kernel": "fd5"}"#);
        assert!(r.get_str("error").unwrap().contains("no fitted model"), "{r}");
        let r = svc.respond("garbage");
        assert!(r.get("error").is_some());
        assert_eq!(svc.summary().errors, 4);
    }

    #[test]
    fn batch_preserves_order_and_counts_batches() {
        let svc = toy_service();
        let lines: Vec<String> = (0..6)
            .map(|i| {
                let case = ["a", "b"][i % 2];
                format!(r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "{case}"}}"#)
            })
            .collect();
        let out = svc.run_batch(lines);
        assert_eq!(out.len(), 6);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.get_f64("id"), Some(i as f64), "{r}");
        }
        assert_eq!(svc.summary().batches, 1);
    }

    #[test]
    fn serve_loop_roundtrips_ldjson() {
        let svc = toy_service();
        let input = "\n".to_string()
            + r#"{"id": 1, "device": "k40c", "kernel": "nbody", "case": "a"}"#
            + "\n"
            + r#"{"id": 2, "device": "k40c", "kernel": "nbody", "case": "a"}"#
            + "\n";
        let mut out = Vec::new();
        let summary = svc.serve(input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r1.get_str("cache"), Some("miss"));
        assert_eq!(r2.get_str("cache"), Some("hit"));
        assert_eq!(r1.get_f64("predicted_s"), r2.get_f64("predicted_s"));
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.cache_hits, 1);
    }

    #[test]
    fn latency_histogram_counts_every_sample_in_bounded_state() {
        // the histogram's state is bounded by construction (65 fixed
        // buckets), yet every observation is counted — unlike the old
        // decimating buffer, heavy traffic loses nothing
        let h = Histogram::new();
        for i in 0..200_000u64 {
            h.observe(i);
        }
        assert_eq!(h.snapshot().count(), 200_000);
        // the service-side accessor reports the exact count
        let svc = toy_service();
        svc.respond(r#"{"device": "k40c", "kernel": "fd5", "case": "a"}"#);
        assert_eq!(svc.latency_samples_held(), 1);
        let s = svc.summary();
        assert!(s.latency_p50_us >= 0.0);
        assert!(s.latency_p90_us >= s.latency_p50_us || s.latency_p90_us == 0.0);
    }

    /// Satellite contract: `{"cmd": "metrics"}` exposes the unified
    /// snapshot as Prometheus text, and the numbers agree with both
    /// the health surface and the summary because all three read
    /// [`Service::metrics_snapshot`].
    #[test]
    fn metrics_cmd_exposes_the_same_snapshot_as_health_and_summary() {
        let svc = toy_service();
        svc.respond(r#"{"device": "k40c", "kernel": "fd5", "case": "a"}"#);
        svc.note_shed();
        svc.note_accept_backoff();
        svc.note_queue_depth(3);
        let m = svc.respond(r#"{"cmd": "metrics", "id": "m1"}"#);
        assert_eq!(m.get_str("ok"), Some("metrics"), "{m}");
        assert_eq!(m.get_str("id"), Some("m1"));
        let text = m.get_str("exposition").unwrap().to_string();
        // the metrics request itself is request #2 and was counted
        // before rendering
        assert!(text.contains("# TYPE uniperf_requests_total counter"), "{text}");
        assert!(text.contains("uniperf_requests_total 2"), "{text}");
        assert!(text.contains("uniperf_cache_misses_total 1"), "{text}");
        assert!(text.contains("uniperf_shed_total 1"), "{text}");
        assert!(text.contains("uniperf_accept_backoffs_total 1"), "{text}");
        assert!(text.contains("# TYPE uniperf_queue_depth gauge"), "{text}");
        assert!(text.contains("uniperf_queue_depth 3"), "{text}");
        assert!(text.contains("# TYPE uniperf_request_latency_us histogram"), "{text}");
        assert!(text.contains("uniperf_request_latency_us_count 1"), "{text}");
        // cross-surface agreement on traffic-independent values
        let s = svc.summary();
        assert_eq!(s.shed, 1);
        assert_eq!(s.queue_depth, 3);
        let h = svc.respond(r#"{"cmd": "health"}"#);
        assert_eq!(h.get("counters").unwrap().get_f64("shed"), Some(1.0), "{h}");
        assert_eq!(h.get("queue").unwrap().get_f64("depth"), Some(3.0), "{h}");
        assert!(
            !text.contains("uniperf_fault_"),
            "no fault plan installed, no fault metrics: {text}"
        );
    }

    #[test]
    fn trace_cmd_reports_recorder_state() {
        let svc = toy_service();
        let t = svc.respond(r#"{"cmd": "trace", "id": 7}"#);
        assert_eq!(t.get_str("ok"), Some("trace"), "{t}");
        assert_eq!(t.get_f64("id"), Some(7.0));
        // the enabled flag is whatever the process-global recorder
        // says (parallel tests may have enabled it); the span arrays
        // are always present
        assert!(t.get("enabled").and_then(Json::as_bool).is_some(), "{t}");
        assert!(matches!(t.get("spans"), Some(Json::Arr(_))), "{t}");
        assert!(matches!(t.get("slow"), Some(Json::Arr(_))), "{t}");
        // trace requests count like any other request, never as errors
        let s = svc.summary();
        assert_eq!((s.requests, s.errors), (1, 0));
    }

    #[test]
    fn interactive_loop_answers_every_line_as_its_own_batch() {
        let svc = toy_service();
        let input = r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#.to_string()
            + "\n"
            + r#"{"id": 2, "device": "k40c", "kernel": "fd5", "case": "a"}"#
            + "\n";
        let mut out = Vec::new();
        let summary = svc.serve_interactive(input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 2);
        // each line was flushed as its own (size-1) batch — the
        // conversational guarantee a blocking TCP client relies on
        assert_eq!(summary.batches, 2);
        assert_eq!(summary.requests, 2);
    }

    #[test]
    fn oversized_inline_group_rejected_for_device() {
        // r9_fury caps groups at 256; a 512-lane inline kernel must be
        // rejected for it
        let svc = Service::new(
            toy_store(&[("r9_fury", 0.0, 1e-6)]),
            builtins().clone(),
            ServiceConfig::default(),
        )
        .unwrap();
        let spec = r#"{"params": ["n"],
            "dims": [{"iname": "g0", "tag": "group0", "hi": "n", "tiles": 512},
                     {"iname": "l0", "tag": "local0", "hi": 512}],
            "arrays": [{"name": "o", "dtype": "f32", "shape": ["n"], "output": true}],
            "insns": [{"store": "o", "idx": ["512*g0 + l0"], "expr": {"lit": 1},
                       "within": ["g0", "l0"]}]}"#;
        let line = format!(r#"{{"device": "r9_fury", "lpir": {spec}, "env": {{"n": 8192}}}}"#);
        let r = svc.respond(&line);
        assert!(r.get_str("error").unwrap().contains("exceeds"), "{r}");
    }

    #[test]
    fn matrix_request_predicts_across_store_devices() {
        let svc = Service::new(
            toy_store(&[("k40c", 2e-9, 5e-6), ("titan_x", 3e-9, 7e-6)]),
            builtins().clone(),
            ServiceConfig { workers: 1, ..ServiceConfig::default() },
        )
        .unwrap();
        let r = svc.respond(r#"{"id": 11, "cmd": "matrix", "kernel": "fd5", "case": "a"}"#);
        assert!(r.get("error").is_none(), "{r}");
        assert_eq!(r.get_str("kernel"), Some("fd5"));
        assert_eq!(r.get_str("case"), Some("a"));
        assert_eq!(r.get_f64("id"), Some(11.0));
        let results = r.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        // per-device predictions equal the single-device responses
        for cell in results {
            let device = cell.get_str("device").unwrap();
            let single = svc.respond(&format!(
                r#"{{"device": "{device}", "kernel": "fd5", "case": "a"}}"#
            ));
            assert_eq!(cell.get_f64("predicted_s"), single.get_f64("predicted_s"), "{device}");
        }
        // one env parse, one extraction: the structure is shared, so
        // only the first device misses
        let s = svc.summary();
        assert_eq!(s.cache_misses, 1, "{s:?}");

        // a named device without weights is a per-cell error
        let r = svc.respond(
            r#"{"cmd": "matrix", "devices": ["k40c", "c2070"], "kernel": "fd5", "case": "a"}"#,
        );
        let results = r.get("results").and_then(Json::as_arr).unwrap();
        assert!(results[0].get("error").is_none());
        assert!(results[1].get_str("error").unwrap().contains("no fitted model"));
        // cell errors are partial results, not request errors
        assert_eq!(svc.summary().errors, 0);
    }

    #[test]
    fn shutdown_request_sets_the_drain_flag_and_stops_the_loop() {
        let svc = toy_service();
        assert!(!svc.shutdown_requested());
        let input = r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#.to_string()
            + "\n"
            + r#"{"id": "bye", "cmd": "shutdown"}"#
            + "\n"
            + r#"{"id": 2, "device": "k40c", "kernel": "fd5", "case": "a"}"#
            + "\n";
        let mut out = Vec::new();
        let summary = svc.serve_interactive(input.as_bytes(), &mut out).unwrap();
        assert!(svc.shutdown_requested());
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // the request after the shutdown command was never read
        assert_eq!(lines.len(), 2, "{text}");
        let bye = Json::parse(lines[1]).unwrap();
        assert_eq!(bye.get_str("ok"), Some("shutdown"));
        assert_eq!(bye.get_str("id"), Some("bye"));
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.errors, 0);
    }

    #[test]
    fn oversized_lines_get_a_bounded_error_and_the_stream_recovers() {
        let svc = Service::new(
            toy_store(&[("k40c", 2e-9, 5e-6)]),
            builtins().clone(),
            ServiceConfig { workers: 1, max_line: 512, ..ServiceConfig::default() },
        )
        .unwrap();
        let padding = "x".repeat(2048);
        let oversized =
            format!(r#"{{"id": 42, "device": "k40c", "kernel": "fd5", "pad": "{padding}"}}"#);
        let input = format!(
            "{oversized}\n{}\n",
            r#"{"id": 43, "device": "k40c", "kernel": "fd5", "case": "a"}"#,
        );
        let mut out = Vec::new();
        let summary = svc.serve(input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "{text}");
        let err = Json::parse(lines[0]).unwrap();
        assert!(err.get_str("error").unwrap().contains("512 byte cap"), "{err}");
        assert_eq!(err.get_f64("id"), Some(42.0), "id salvaged from the retained prefix");
        // the stream resynchronized at the newline: the next request
        // is answered normally
        let ok = Json::parse(lines[1]).unwrap();
        assert_eq!(ok.get_f64("id"), Some(43.0));
        assert!(ok.get("error").is_none(), "{ok}");
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.errors, 1);
    }

    #[test]
    fn salvage_id_handles_scalars_and_garbage() {
        assert_eq!(salvage_id(br#"{"id": 7, "device"#), Some(Json::Num(7.0)));
        assert_eq!(salvage_id(br#"{"id": -2.5e3,"#), Some(Json::Num(-2500.0)));
        assert_eq!(
            salvage_id(br#"{"device": "x", "id": "q-1", junk"#),
            Some(Json::Str("q-1".into()))
        );
        assert_eq!(salvage_id(br#"{"device": "x""#), None);
        assert_eq!(salvage_id(br#"{"id": "#), None);
        assert_eq!(salvage_id(br#"{"id" "x""#), None);
        assert_eq!(salvage_id(b"\xff\xfe"), None);
    }

    #[test]
    fn capped_reader_handles_boundaries() {
        // exactly at the cap: fine
        let mut r = std::io::BufReader::new(&b"abcd\nefgh"[..]);
        match read_request_line(&mut r, 4, &|| false).unwrap() {
            ReadLine::Line(l) => assert_eq!(l, "abcd"),
            _ => panic!("line at the cap must pass"),
        }
        // trailing line without newline
        match read_request_line(&mut r, 4, &|| false).unwrap() {
            ReadLine::Line(l) => assert_eq!(l, "efgh"),
            _ => panic!("final unterminated line must pass"),
        }
        assert!(matches!(read_request_line(&mut r, 4, &|| false).unwrap(), ReadLine::Eof));
        // one past the cap: oversized, and the stream resumes after
        let mut r = std::io::BufReader::new(&b"abcde\nok\n"[..]);
        assert!(matches!(
            read_request_line(&mut r, 4, &|| false).unwrap(),
            ReadLine::Oversized { .. }
        ));
        match read_request_line(&mut r, 4, &|| false).unwrap() {
            ReadLine::Line(l) => assert_eq!(l, "ok"),
            _ => panic!("stream must resynchronize at the newline"),
        }
    }

    #[test]
    fn expired_deadlines_are_answered_with_a_reason() {
        let svc = toy_service();
        // a zero budget is always already spent by execution time
        let r = svc.respond(
            r#"{"id": 9, "device": "k40c", "kernel": "fd5", "case": "a", "deadline_ms": 0}"#,
        );
        assert!(r.get_str("error").unwrap().contains("deadline"), "{r}");
        assert_eq!(r.get_str("reason"), Some("deadline"));
        assert_eq!(r.get_f64("id"), Some(9.0));
        assert!(r.get("predicted_s").is_none(), "an expired request is never predicted");
        // a generous budget is not expired
        let r = svc.respond(
            r#"{"device": "k40c", "kernel": "fd5", "case": "a", "deadline_ms": 60000}"#,
        );
        assert!(r.get("error").is_none(), "{r}");
        // matrix requests carry the same budget
        let r = svc.respond(r#"{"cmd": "matrix", "kernel": "fd5", "deadline_ms": 0}"#);
        assert_eq!(r.get_str("reason"), Some("deadline"), "{r}");
        let s = svc.summary();
        assert_eq!((s.requests, s.errors, s.deadline_expired), (3, 2, 2));
    }

    #[test]
    fn bounded_queue_sheds_overload_in_stream_order() {
        let svc = Service::new(
            toy_store(&[("k40c", 2e-9, 5e-6)]),
            builtins().clone(),
            ServiceConfig { workers: 1, batch: 8, queue_cap: 2, ..ServiceConfig::default() },
        )
        .unwrap();
        let input: String = (0..6)
            .map(|i| {
                format!(r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "a"}}"#)
                    + "\n"
            })
            .collect();
        let mut out = Vec::new();
        let summary = svc.serve(input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 6, "every line gets exactly one response:\n{text}");
        for (i, l) in lines.iter().enumerate() {
            let j = Json::parse(l).unwrap();
            assert_eq!(j.get_f64("id"), Some(i as f64), "stream order: {l}");
            if i < 2 {
                assert!(j.get("error").is_none(), "{l}");
            } else {
                assert!(j.get_str("error").unwrap().contains("overloaded"), "{l}");
                assert_eq!(j.get_str("reason"), Some("overloaded"), "{l}");
                assert_eq!(j.get_f64("retry_after_ms"), Some(RETRY_AFTER_MS as f64));
            }
        }
        assert_eq!((summary.requests, summary.errors, summary.shed), (6, 4, 4));
    }

    #[test]
    fn health_and_stats_report_component_status() {
        let svc = toy_service();
        svc.respond(r#"{"device": "k40c", "kernel": "fd5", "case": "a"}"#);
        let h = svc.respond(r#"{"cmd": "health", "id": "h1"}"#);
        assert_eq!(h.get_str("ok"), Some("health"), "{h}");
        assert_eq!(h.get_str("id"), Some("h1"));
        let store = h.get("store").unwrap();
        assert_eq!(
            store.get_str("fingerprint"),
            Some(svc.store().fingerprint().as_str())
        );
        assert_eq!(store.get("devices").and_then(Json::as_arr).unwrap().len(), 1);
        let reloader = h.get("reloader").unwrap();
        assert_eq!(reloader.get("watching").and_then(Json::as_bool), Some(false));
        assert_eq!(reloader.get("last_error"), Some(&Json::Null));
        let cache = h.get("cache").unwrap();
        assert_eq!(cache.get_f64("misses"), Some(1.0), "{cache}");
        assert!(cache.get_f64("capacity").unwrap() > 0.0);
        assert_eq!(h.get_f64("quarantined"), Some(0.0));
        assert_eq!(h.get("breakers").unwrap().get_f64("open"), Some(0.0));
        assert_eq!(h.get("faults"), Some(&Json::Null), "no plan installed");
        // stats wraps the same summary the serve loop prints; health
        // and stats count as requests, never as errors
        let st = svc.respond(r#"{"cmd": "stats"}"#);
        assert_eq!(st.get_str("ok"), Some("stats"), "{st}");
        let sum = st.get("summary").unwrap();
        assert_eq!(sum.get_f64("errors"), Some(0.0));
        assert_eq!(sum.get_f64("requests"), Some(3.0));
        assert_eq!(sum.get_f64("shed"), Some(0.0));
        assert_eq!(svc.summary().errors, 0);
    }

    #[test]
    fn degraded_predictions_surface_their_fallback_device() {
        use crate::engine::{Config, Engine};
        let engine = Engine::new(Config {
            registry: builtins().clone(),
            workers: 1,
            degraded: true,
            ..Config::default()
        });
        engine.install_store(toy_store(&[("k40c", 2e-9, 5e-6)])).unwrap();
        let svc = Service::over(
            Arc::new(engine),
            ServiceConfig { workers: 1, ..ServiceConfig::default() },
        )
        .unwrap();
        let r = svc.respond(r#"{"id": 3, "device": "titan_x", "kernel": "fd5", "case": "a"}"#);
        assert!(r.get("error").is_none(), "{r}");
        assert_eq!(r.get("degraded"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get_str("served_by"), Some("k40c"));
        assert_eq!(r.get_str("device"), Some("titan_x"), "the requested device is echoed");
        assert_eq!(svc.summary().degraded_served, 1);
        // a direct hit is never flagged
        let r = svc.respond(r#"{"device": "k40c", "kernel": "fd5", "case": "a"}"#);
        assert!(r.get("degraded").is_none(), "{r}");
        // matrix cells flag per device
        let r = svc.respond(
            r#"{"cmd": "matrix", "devices": ["k40c", "titan_x"], "kernel": "fd5", "case": "a"}"#,
        );
        let cells = r.get("results").and_then(Json::as_arr).unwrap();
        assert!(cells[0].get("degraded").is_none(), "{r}");
        assert_eq!(cells[1].get("degraded"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(cells[1].get_str("served_by"), Some("k40c"));
    }

    /// The reactor's rendering path: one formed batch answers exactly
    /// like the same lines fed through sequential `respond` calls on
    /// an identical fresh service (same bytes, same hit/miss
    /// sequence), and records one batch of the right width.
    #[test]
    fn respond_batch_matches_sequential_respond_and_records_width() {
        let svc = toy_service();
        let reference = toy_service();
        let lines = [
            r#"{"id": 0, "device": "k40c", "kernel": "fd5", "case": "a"}"#,
            r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "b"}"#,
            r#"{"id": 2, "device": "k40c", "kernel": "nope"}"#,
            r#"not json"#,
        ];
        let now = Instant::now();
        let batch: Vec<(String, Instant)> =
            lines.iter().map(|l| (l.to_string(), now)).collect();
        let got = svc.respond_batch(batch, 1);
        assert_eq!(got.len(), lines.len());
        for (line, g) in lines.iter().zip(&got) {
            assert_eq!(g.compact(), reference.respond(line).compact(), "{line}");
        }
        let s = svc.summary();
        assert_eq!(s.requests, 4);
        assert_eq!(s.errors, 2);
        // exactly one width-4 batch was formed; `respond` never counts
        // one (the reference saw four width-1 calls through
        // answer_batch, not respond_batch)
        assert_eq!((s.batch_p50, s.batch_p99, s.batch_mean), (4.0, 4.0, 4.0));
        let r = reference.summary();
        assert_eq!((r.batch_p50, r.batch_p99, r.batch_mean), (0.0, 0.0, 0.0));
    }

    /// Accept failures always count, but only the first per errno per
    /// window is printed — with the suppressed repeats annotated on
    /// the next printed line. A distinct errno logs immediately.
    #[test]
    fn accept_errors_count_every_failure_but_rate_limit_the_log() {
        let svc = toy_service();
        let reset = || std::io::Error::from_raw_os_error(104); // ECONNRESET
        let msg = svc.note_accept_error(&reset()).expect("first failure logs");
        assert!(msg.contains("accept failed"), "{msg}");
        assert!(svc.note_accept_error(&reset()).is_none(), "repeat is silent");
        assert!(svc.note_accept_error(&reset()).is_none());
        let emfile = std::io::Error::from_raw_os_error(24); // EMFILE
        assert!(
            svc.note_accept_error(&emfile).is_some(),
            "a distinct errno is not suppressed by another's window"
        );
        assert_eq!(svc.summary().accept_errors, 4, "every failure counted");
        let h = svc.respond(r#"{"cmd": "health"}"#);
        assert_eq!(
            h.get("counters").unwrap().get_f64("accept_errors"),
            Some(4.0),
            "{h}"
        );
    }

    /// The serving knobs are observable: queue depth/cap, the
    /// formed-batch width percentiles and the accept-backoff counter
    /// all surface through health and the summary.
    #[test]
    fn queue_and_batch_observability_surfaces_in_health_and_summary() {
        let svc = toy_service();
        let now = Instant::now();
        let batch: Vec<(String, Instant)> = (0..4)
            .map(|i| {
                let line =
                    format!(r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "a"}}"#);
                (line, now)
            })
            .collect();
        svc.respond_batch(batch, 1);
        svc.note_queue_depth(2);
        svc.note_accept_backoff();
        let h = svc.respond(r#"{"cmd": "health"}"#);
        let queue = h.get("queue").unwrap();
        assert_eq!(queue.get_f64("depth"), Some(2.0), "{h}");
        assert_eq!(queue.get_f64("cap"), Some(4096.0), "default queue bound: {h}");
        let widths = h.get("batch").unwrap();
        assert_eq!(widths.get_f64("width_p50"), Some(4.0), "{h}");
        assert_eq!(widths.get_f64("width_p99"), Some(4.0), "{h}");
        assert_eq!(widths.get_f64("width_mean"), Some(4.0), "{h}");
        assert_eq!(
            h.get("counters").unwrap().get_f64("accept_backoffs"),
            Some(1.0),
            "{h}"
        );
        let s = svc.summary();
        assert_eq!(s.queue_depth, 2);
        assert_eq!(s.accept_backoffs, 1);
    }
}
