//! The threaded TCP listener: per-connection threads over one shared
//! `Arc<Service>`, with a resilient accept loop and deterministic
//! drain.
//!
//! The original `serve --port` loop served connections *serially*: a
//! slow client blocked every other client for the life of its
//! connection. Here every accepted connection gets its own OS thread
//! running the conversational loop
//! ([`Service::serve_interactive`]-style: each request line answered
//! and flushed before the next read); all threads share one service —
//! one engine, one props cache, one hot-swappable store — so a kernel
//! structure extracted for one client is a cache hit for every other.
//!
//! Resilience and drain:
//!
//! * a failed `accept` (client reset mid-handshake, transient fd
//!   exhaustion) is logged and skipped, never fatal;
//! * a **connection-count guard** caps concurrent connections: above
//!   the cap a connection is answered with one `{"error": ...}` line
//!   and closed, so a connection flood degrades loudly instead of
//!   spawning unbounded threads;
//! * `{"cmd": "shutdown"}` (on any connection) flags the service; the
//!   flagging connection's loop ends after flushing the response, a
//!   wake connection unblocks the accept call, and
//!   [`serve_threaded`] **joins every connection thread** before
//!   returning — when it returns, the listener is provably drained
//!   (tests and benches rely on this determinism);
//! * when the service watches a `--models` file, the artifact is
//!   re-statted before each accepted connection (and between batches
//!   inside each connection loop), so a refit reaches a long-lived
//!   server without a restart.

use super::Service;
use crate::obs::log::Level;
use crate::olog;
use crate::report::ServiceSummary;
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Default connection-count guard for [`serve_threaded`].
pub const DEFAULT_MAX_CONNECTIONS: usize = 256;

/// Serve `listener` with one thread per connection until a shutdown
/// request drains it. Returns the service summary once every
/// connection thread has been joined.
pub fn serve_threaded(
    svc: &Arc<Service>,
    listener: TcpListener,
    max_connections: usize,
) -> Result<ServiceSummary, String> {
    let addr = listener
        .local_addr()
        .map_err(|e| format!("listener address: {e}"))?;
    let max_connections = max_connections.max(1);
    let active = Arc::new(AtomicUsize::new(0));
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    for stream in listener.incoming() {
        if svc.shutdown_requested() {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                // a failed accept must not take the listener down; the
                // service counts every failure but rate-limits the log
                // to one line per errno per window (SYN churn would
                // otherwise flood stderr)
                if let Some(msg) = svc.note_accept_error(&e) {
                    olog!(Level::Warn, "uniperf serve: {msg}");
                }
                continue;
            }
        };
        if svc.shutdown_requested() {
            // the accept was the shutdown wake-up call
            break;
        }
        // chaos: the conn.abort fault site drops an accepted connection
        // before a single byte is served — clients observe a reset and
        // must retry, the request accounting is untouched
        if let Some(plan) = svc.fault_plan() {
            if plan.should_inject("conn.abort") {
                svc.note_conn_aborted();
                drop(stream);
                continue;
            }
        }
        // hot reload between connections (batch loops poll it too)
        if let Some(Err(e)) = svc.poll_reload() {
            olog!(
                Level::Warn,
                "uniperf serve: artifact reload failed (keeping current models): {e}"
            );
        }
        // connection-count guard: shed load loudly instead of
        // spawning unbounded threads
        if active.load(Ordering::SeqCst) >= max_connections {
            let mut s = stream;
            let resp = svc.conn_guard_response(max_connections);
            let _ = writeln!(s, "{}", resp.compact());
            continue;
        }
        active.fetch_add(1, Ordering::SeqCst);
        let svc = Arc::clone(svc);
        let active = Arc::clone(&active);
        handles.push(std::thread::spawn(move || {
            serve_one(&svc, stream, addr);
            active.fetch_sub(1, Ordering::SeqCst);
        }));
        // reap finished threads so a long-lived listener's handle list
        // stays proportional to *live* connections
        handles.retain(|h| !h.is_finished());
    }
    // drain: every connection thread has finished when this returns
    for h in handles {
        let _ = h.join();
    }
    debug_assert_eq!(active.load(Ordering::SeqCst), 0);
    Ok(svc.summary())
}

/// How long a connection read blocks before re-checking the shutdown
/// flag. Bounds the drain latency of threads parked on idle sockets:
/// without it, a keep-alive client that never sends another line would
/// pin its thread in `read` past shutdown and the final join would
/// wait on the client's goodwill.
const READ_POLL: std::time::Duration = std::time::Duration::from_millis(250);

/// How long the `conn.slow` fault site stalls a freshly accepted
/// connection (shared with the reactor transport, which defers the
/// first read by the same amount). Short enough to keep chaos tests
/// fast, long enough to overlap other connections' traffic.
pub(crate) const SLOW_CONN_DELAY: std::time::Duration = std::time::Duration::from_millis(25);

/// One connection: the conversational loop, then (if this connection
/// carried the shutdown command) a wake connection so the blocked
/// accept call observes the drain flag.
fn serve_one(svc: &Arc<Service>, stream: TcpStream, addr: std::net::SocketAddr) {
    // chaos: the conn.slow fault site stalls this connection before its
    // first read — the client's requests still all get answered, just
    // late (deadline budgets and the drain logic must both survive it)
    if let Some(plan) = svc.fault_plan() {
        if plan.should_inject("conn.slow") {
            svc.note_conn_slowed();
            std::thread::sleep(SLOW_CONN_DELAY);
        }
    }
    // a timeout-shaped read error makes the serving loop re-check the
    // shutdown flag (see `read_request_line`) instead of blocking
    // forever on an idle socket
    if let Err(e) = stream.set_read_timeout(Some(READ_POLL)) {
        olog!(Level::Warn, "uniperf serve: connection setup failed: {e}");
        return;
    }
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => {
            olog!(Level::Warn, "uniperf serve: connection setup failed: {e}");
            return;
        }
    };
    if let Err(e) = svc.serve_connection(reader, stream) {
        // a broken client must not take the listener down
        olog!(Level::Warn, "uniperf serve: connection error: {e}");
    }
    if svc.shutdown_requested() {
        // unblock the accept loop; any connection works, including a
        // redundant one from a second shutdown racer
        let _ = TcpStream::connect(addr);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::gpusim::registry::builtins;
    use crate::service::testutil::toy_store;
    use crate::service::ServiceConfig;
    use crate::util::json::Json;
    use std::io::BufRead;

    fn toy_service() -> Service {
        let store = toy_store(&[("k40c", 2e-9, 5e-6)]);
        Service::new(store, builtins().clone(), ServiceConfig::default()).unwrap()
    }

    /// Send `lines` conversationally; return the response lines.
    fn client(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
        let stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut stream = stream;
        let mut out = Vec::new();
        for line in lines {
            writeln!(stream, "{line}").expect("send");
            stream.flush().expect("flush");
            let mut resp = String::new();
            reader.read_line(&mut resp).expect("recv");
            out.push(resp.trim_end().to_string());
        }
        out
    }

    /// The deterministic-drain contract: clients get conversational
    /// answers from per-connection threads, a shutdown command stops
    /// the accept loop, and `serve_threaded` returns only after every
    /// connection thread joined. (The N-client concurrency/accounting
    /// test lives in `rust/tests/engine.rs`.)
    #[test]
    fn threaded_listener_serves_and_drains_on_shutdown() {
        let svc = Arc::new(toy_service());
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
        let addr = listener.local_addr().unwrap();
        let server = {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || serve_threaded(&svc, listener, 8).expect("serve"))
        };

        let lines: Vec<String> = (0..4)
            .map(|i| format!(r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "a"}}"#))
            .collect();
        let responses = client(addr, &lines);
        assert_eq!(responses.len(), 4);
        for (i, r) in responses.iter().enumerate() {
            let j = Json::parse(r).unwrap();
            assert!(j.get("error").is_none(), "{r}");
            assert_eq!(j.get_f64("id"), Some(i as f64));
        }

        let bye = client(addr, &[r#"{"cmd": "shutdown", "id": "drain"}"#.to_string()]);
        let j = Json::parse(&bye[0]).unwrap();
        assert_eq!(j.get_str("ok"), Some("shutdown"));
        let summary = server.join().expect("server thread");
        assert!(svc.shutdown_requested());
        assert_eq!(summary.requests, 5);
        assert_eq!(summary.errors, 0);
    }

    /// The drain must not depend on clients' goodwill: a connection
    /// that sits idle (open, never sending) is unblocked by the read
    /// poll when shutdown arrives, and `serve_threaded` still joins
    /// everything and returns while the idle client remains connected.
    #[test]
    fn shutdown_drains_even_with_an_idle_connection_open() {
        let svc = Arc::new(toy_service());
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
        let addr = listener.local_addr().unwrap();
        let server = {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || serve_threaded(&svc, listener, 8).expect("serve"))
        };

        // an idle connection: opened, held, never written to
        let idle = TcpStream::connect(addr).expect("idle connect");
        // prove it reached the server loop (one real request after it)
        let r = client(addr, &[r#"{"device": "k40c", "kernel": "fd5", "case": "a"}"#.to_string()]);
        assert!(Json::parse(&r[0]).unwrap().get("error").is_none());

        client(addr, &[r#"{"cmd": "shutdown"}"#.to_string()]);
        // must return despite the idle connection still being open —
        // its thread wakes on the read poll and observes the flag
        let summary = server.join().expect("server drains with idle client attached");
        assert_eq!(summary.errors, 0);
        // only now does the idle client go away
        drop(idle);
    }
}
