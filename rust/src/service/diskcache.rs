//! `diskcache` — a persistent, append-only extraction-cache file.
//!
//! Symbolic extraction is the expensive step of serving (milliseconds
//! per novel kernel structure vs. microseconds on the compiled tape
//! path), and its result is *pure*: a function of the kernel structure
//! (the rename-invariant [`super::hash`] key), the extraction options
//! and the classification-relevant environment bindings (the env
//! salt). That makes it safe to share across processes: a
//! [`PropsCacheFile`] records every extraction as one JSON line, and a
//! restarted (or scaled-out) `serve` instance preloads the file and
//! answers its in-memory misses from it — zero extractions on a warm
//! corpus (`rust/tests/service.rs` pins the kill-then-restart path).
//!
//! ## File format (`uniperf-propscache-v1`)
//!
//! Line-delimited JSON. Line 1 is the header:
//!
//! ```json
//! {"format": "uniperf-propscache-v1", "schema": "<fingerprint>",
//!  "collapse_utilization": false, "bin_local_strides": false}
//! ```
//!
//! Every later line is one cached extraction:
//!
//! ```json
//! {"hash": "<16-hex structural hash>", "salt": "<16-hex env salt>",
//!  "props": {"kernel": ..., "props": {...}}}
//! ```
//!
//! ## Trust model: validate, never assume
//!
//! A cache file is an *optimization*, not an authority. [`open`]
//! refuses a file whose format tag, schema fingerprint or extraction
//! options disagree with this build — the caller warns and starts
//! cold; a mismatched file is never read from or appended to (its
//! entries would silently poison predictions across a schema change).
//! A torn tail — the crash-truncated last line an append-only log can
//! always have — is tolerated: loading stops at the first unparseable
//! or incomplete line with one warning, keeping every entry before it.
//! Appends are single `write(2)` calls of one complete line, so
//! concurrent writers and crashes can tear at most the final line.
//!
//! [`open`]: PropsCacheFile::open

use crate::obs::log::Level;
use crate::olog;
use crate::stats::{ExtractOpts, KernelProps, Schema};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// The cache-file format this build writes and reads.
pub const FORMAT: &str = "uniperf-propscache-v1";

/// Poison-tolerant lock (same posture as the serving cache: a torn
/// in-memory map beats a cascading panic in a serving loop).
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A loaded + appendable extraction-cache file. See the module docs
/// for the format and trust model. All methods are `&self`:
/// [`SharedPropsCache`](super::SharedPropsCache) holds one behind an
/// `Arc` and consults it from every shard.
pub struct PropsCacheFile {
    opts: ExtractOpts,
    /// preloaded entries, keyed `(structural hash, env salt)`
    entries: Mutex<BTreeMap<(u64, u64), Arc<KernelProps>>>,
    /// append handle; one complete line per `write`
    file: Mutex<std::fs::File>,
    /// entries preloaded from disk at open (excludes later appends)
    loaded: usize,
}

impl PropsCacheFile {
    /// Open (or create) the cache file at `path` for this build's
    /// `schema` and `opts`.
    ///
    /// A missing or empty file is created with a fresh header. An
    /// existing file must carry a matching header — format tag, schema
    /// fingerprint and extraction options — or this returns `Err` and
    /// the file is left untouched: the caller logs the reason and runs
    /// cold rather than trusting incompatible entries. Unreadable
    /// trailing lines (a torn append) stop loading with one warning;
    /// everything before them is kept.
    pub fn open(
        path: &Path,
        schema: &Schema,
        opts: ExtractOpts,
    ) -> Result<PropsCacheFile, String> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(format!("props cache {}: {e}", path.display())),
        };
        let header = Json::obj(vec![
            ("format", Json::Str(FORMAT.into())),
            ("schema", Json::Str(schema.fingerprint())),
            ("collapse_utilization", Json::Bool(opts.collapse_utilization)),
            ("bin_local_strides", Json::Bool(opts.bin_local_strides)),
        ]);
        let mut lines = text.lines();
        let fresh = match lines.next() {
            None => true,
            Some(first) => {
                let j = Json::parse(first).map_err(|e| {
                    format!("props cache {}: unreadable header: {e}", path.display())
                })?;
                super::store::check_format(&j, FORMAT, "props cache")?;
                match j.get_str("schema") {
                    Some(fp) if fp == schema.fingerprint() => {}
                    Some(fp) => {
                        return Err(format!(
                            "props cache {}: schema fingerprint {fp} does not match \
                             this build ({})",
                            path.display(),
                            schema.fingerprint()
                        ))
                    }
                    None => {
                        return Err(format!(
                            "props cache {}: header missing 'schema'",
                            path.display()
                        ))
                    }
                }
                let file_opts = ExtractOpts {
                    collapse_utilization: j
                        .get("collapse_utilization")
                        .and_then(Json::as_bool)
                        .ok_or_else(|| {
                            format!(
                                "props cache {}: header missing 'collapse_utilization'",
                                path.display()
                            )
                        })?,
                    bin_local_strides: j
                        .get("bin_local_strides")
                        .and_then(Json::as_bool)
                        .ok_or_else(|| {
                            format!(
                                "props cache {}: header missing 'bin_local_strides'",
                                path.display()
                            )
                        })?,
                };
                if file_opts != opts {
                    return Err(format!(
                        "props cache {}: extraction options {file_opts:?} do not \
                         match this configuration ({opts:?})",
                        path.display()
                    ));
                }
                false
            }
        };

        // entries: stop at the first torn/invalid line (append-only
        // logs can always have a crash-truncated tail), keep the rest
        let mut entries: BTreeMap<(u64, u64), Arc<KernelProps>> = BTreeMap::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            match parse_entry(line) {
                Ok((key, props)) => {
                    entries.insert(key, Arc::new(props));
                }
                Err(e) => {
                    olog!(
                        Level::Warn,
                        "uniperf: props cache {}: line {}: {e}; keeping the {} entries \
                         before it and ignoring the rest",
                        path.display(),
                        i + 2,
                        entries.len()
                    );
                    break;
                }
            }
        }
        let loaded = entries.len();

        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("props cache {}: open for append: {e}", path.display()))?;
        if fresh {
            file.write_all(format!("{}\n", header.compact()).as_bytes())
                .map_err(|e| format!("props cache {}: write header: {e}", path.display()))?;
        }
        Ok(PropsCacheFile {
            opts,
            entries: Mutex::new(entries),
            file: Mutex::new(file),
            loaded,
        })
    }

    /// The extraction options pinned by this file's header. The
    /// in-memory cache only routes lookups with *matching* options
    /// through this file.
    pub fn opts(&self) -> ExtractOpts {
        self.opts
    }

    /// A preloaded (or previously appended) extraction for the given
    /// structural hash + env salt.
    pub fn lookup(&self, hash: u64, salt: u64) -> Option<Arc<KernelProps>> {
        locked(&self.entries).get(&(hash, salt)).map(Arc::clone)
    }

    /// Record a fresh extraction: one complete JSON line, appended
    /// under the file lock in a single write. Persistence is
    /// best-effort — a full disk degrades the *next* process's warm
    /// start, never this request — but the in-memory copy is always
    /// kept so repeated appends of the same key stay idempotent.
    pub fn append(&self, hash: u64, salt: u64, props: &Arc<KernelProps>) {
        let line = Json::obj(vec![
            ("hash", Json::Str(format!("{hash:016x}"))),
            ("salt", Json::Str(format!("{salt:016x}"))),
            ("props", props.to_json()),
        ]);
        {
            let mut entries = locked(&self.entries);
            if entries.contains_key(&(hash, salt)) {
                return;
            }
            entries.insert((hash, salt), Arc::clone(props));
        }
        let mut f = locked(&self.file);
        let _ = f.write_all(format!("{}\n", line.compact()).as_bytes());
    }

    /// Entries currently held (preloaded + appended).
    pub fn len(&self) -> usize {
        locked(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        locked(&self.entries).is_empty()
    }

    /// Entries preloaded from disk when the file was opened — the warm
    /// start a predecessor process handed this one.
    pub fn loaded(&self) -> usize {
        self.loaded
    }
}

/// Parse one entry line into its key and properties.
fn parse_entry(line: &str) -> Result<((u64, u64), KernelProps), String> {
    let j = Json::parse(line).map_err(|e| format!("unreadable entry: {e}"))?;
    let hex = |field: &str| -> Result<u64, String> {
        let s = j
            .get_str(field)
            .ok_or_else(|| format!("entry missing '{field}'"))?;
        u64::from_str_radix(s, 16).map_err(|e| format!("entry '{field}': {e}"))
    };
    let hash = hex("hash")?;
    let salt = hex("salt")?;
    let props = j
        .get("props")
        .ok_or_else(|| "entry missing 'props'".to_string())
        .and_then(KernelProps::from_json)?;
    Ok(((hash, salt), props))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernels;
    use crate::stats::extract;

    /// A unique temp path per test (no tempdir dependency; collisions
    /// avoided via the test name).
    fn tmp(name: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("uniperf_diskcache_{name}_{}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sample_props() -> KernelProps {
        let dev = crate::gpusim::registry::builtins().get("k40c").unwrap();
        let case = kernels::eval_suite(dev)
            .into_iter()
            .find(|c| c.label.starts_with("fd5/a/"))
            .unwrap();
        extract(&case.kernel, &case.env, ExtractOpts::default()).unwrap()
    }

    #[test]
    fn round_trips_entries_across_open() {
        let path = tmp("round_trip");
        let schema = Schema::full();
        let opts = ExtractOpts::default();
        let props = Arc::new(sample_props());
        {
            let f = PropsCacheFile::open(&path, &schema, opts).unwrap();
            assert_eq!(f.loaded(), 0, "fresh file preloads nothing");
            f.append(0xdead_beef, 0x42, &props);
            f.append(0xdead_beef, 0x42, &props); // idempotent
            f.append(0xcafe, 0x42, &props);
            assert_eq!(f.len(), 2);
        }
        let f = PropsCacheFile::open(&path, &schema, opts).unwrap();
        assert_eq!(f.loaded(), 2, "restart preloads both entries");
        let got = f.lookup(0xdead_beef, 0x42).unwrap();
        let env = crate::qpoly::env(&[("n", 1 << 20)]);
        assert_eq!(
            got.eval(&schema, &env).unwrap(),
            props.eval(&schema, &env).unwrap(),
            "reloaded props evaluate identically"
        );
        assert!(f.lookup(0xdead_beef, 0x43).is_none(), "salt is part of the key");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn refuses_mismatched_headers() {
        let path = tmp("mismatch");
        let schema = Schema::full();
        let opts = ExtractOpts::default();
        drop(PropsCacheFile::open(&path, &schema, opts).unwrap());
        // options mismatch
        let other = ExtractOpts { collapse_utilization: true, ..opts };
        let e = PropsCacheFile::open(&path, &schema, other).unwrap_err();
        assert!(e.contains("extraction options"), "{e}");
        // schema mismatch: rewrite the header with a bogus fingerprint
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace(&schema.fingerprint(), "0000000000000bad")).unwrap();
        let e = PropsCacheFile::open(&path, &schema, opts).unwrap_err();
        assert!(e.contains("schema fingerprint"), "{e}");
        // format mismatch
        std::fs::write(&path, "{\"format\": \"uniperf-propscache-v999\"}\n").unwrap();
        let e = PropsCacheFile::open(&path, &schema, opts).unwrap_err();
        assert!(e.contains("format"), "{e}");
        // tagless garbage
        std::fs::write(&path, "{\"hello\": 1}\n").unwrap();
        let e = PropsCacheFile::open(&path, &schema, opts).unwrap_err();
        assert!(e.contains("missing 'format'"), "{e}");
        // unparseable header
        std::fs::write(&path, "not json at all\n").unwrap();
        let e = PropsCacheFile::open(&path, &schema, opts).unwrap_err();
        assert!(e.contains("unreadable header"), "{e}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tolerates_a_torn_tail() {
        let path = tmp("torn");
        let schema = Schema::full();
        let opts = ExtractOpts::default();
        let props = Arc::new(sample_props());
        {
            let f = PropsCacheFile::open(&path, &schema, opts).unwrap();
            f.append(1, 0, &props);
            f.append(2, 0, &props);
        }
        // simulate a crash mid-append: truncate the last line
        let text = std::fs::read_to_string(&path).unwrap();
        let keep = text.len() - 40;
        std::fs::write(&path, &text[..keep]).unwrap();
        let f = PropsCacheFile::open(&path, &schema, opts).unwrap();
        assert_eq!(f.loaded(), 1, "entries before the torn line survive");
        assert!(f.lookup(1, 0).is_some());
        assert!(f.lookup(2, 0).is_none(), "the torn entry is dropped, not trusted");
        // the file is still appendable after recovery
        f.append(3, 0, &props);
        drop(f);
        let f = PropsCacheFile::open(&path, &schema, opts).unwrap();
        // note: the torn fragment still sits mid-file, so loading still
        // stops there — recovery is bounded by the first tear until the
        // file is rewritten. The entry *before* the tear is what a
        // restart is guaranteed to keep.
        assert!(f.lookup(1, 0).is_some());
        let _ = std::fs::remove_file(&path);
    }
}
