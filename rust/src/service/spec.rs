//! Inline `lpir` kernel specs: a JSON encoding of [`Kernel`] so service
//! clients can request predictions for kernels the library has never
//! seen, without recompiling anything.
//!
//! ```json
//! {
//!   "name": "scale2",
//!   "params": ["n"],
//!   "dims": [
//!     {"iname": "g0", "tag": "group0", "hi": "n", "tiles": 256},
//!     {"iname": "l0", "tag": "local0", "hi": 256}
//!   ],
//!   "arrays": [
//!     {"name": "a", "dtype": "f32", "shape": ["n"]},
//!     {"name": "b", "dtype": "f32", "shape": ["n"], "output": true}
//!   ],
//!   "insns": [
//!     {"store": "b", "idx": ["256*g0 + l0"],
//!      "expr": {"mul": [{"lit": 2}, {"load": {"array": "a", "idx": ["256*g0 + l0"]}}]},
//!      "within": ["g0", "l0"]}
//!   ]
//! }
//! ```
//!
//! Index and shape entries are affine strings over parameters and
//! inames (`"256*g0 + l0 - 1"`) or plain numbers. Expression objects
//! carry exactly one operative key: `lit`, `idx`, `load`, the binary
//! ops `add|sub|mul|div|pow|min|max` (a two-element array), the unary
//! ops `neg|sqrt|rsqrt|exp|sin|cos|abs`, the reductions `sum|rmax`
//! (`{"iname": ..., "body": ...}`) and `cast`
//! (`{"dtype": ..., "expr": ...}`). The assembled kernel passes
//! [`Kernel::validate`] before it is accepted.

use crate::isl::{BoxDomain, CeilDiv, Dim};
use crate::lpir::{
    Access, ArrayDecl, BinOp, DType, Expr, IdxTag, Insn, Kernel, Layout, MemSpace, RedOp,
    UnOp,
};
use crate::qpoly::LinExpr;
use crate::util::json::Json;
use crate::util::intern::Sym;
use std::collections::BTreeMap;

/// Parse an affine expression string: a `+`/`-` separated sum of terms,
/// each a product of integers and at most one identifier.
pub fn parse_affine(s: &str) -> Result<LinExpr, String> {
    #[derive(PartialEq)]
    enum Tok {
        Num(i64),
        Ident(String),
        Plus,
        Minus,
        Star,
    }
    let mut toks = Vec::new();
    let b = s.as_bytes();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b' ' | b'\t' => i += 1,
            b'+' => {
                toks.push(Tok::Plus);
                i += 1;
            }
            b'-' => {
                toks.push(Tok::Minus);
                i += 1;
            }
            b'*' => {
                toks.push(Tok::Star);
                i += 1;
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                let n: i64 = s[start..i]
                    .parse()
                    .map_err(|_| format!("affine '{s}': number out of range"))?;
                toks.push(Tok::Num(n));
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || b[i] == b'_')
                {
                    i += 1;
                }
                toks.push(Tok::Ident(s[start..i].to_string()));
            }
            c => return Err(format!("affine '{s}': unexpected character '{}'", c as char)),
        }
    }

    if toks.is_empty() {
        return Err(format!("empty affine expression '{s}'"));
    }
    let mut out = LinExpr::constant(0);
    let mut pos = 0usize;
    loop {
        // sign
        let mut sign = 1i64;
        while pos < toks.len() && matches!(toks[pos], Tok::Plus | Tok::Minus) {
            if toks[pos] == Tok::Minus {
                sign = -sign;
            }
            pos += 1;
        }
        if pos >= toks.len() {
            return Err(format!("affine '{s}': dangling sign"));
        }
        // term: factors joined by '*'
        let mut coeff = 1i64;
        let mut ident: Option<String> = None;
        loop {
            match &toks[pos] {
                Tok::Num(n) => coeff = coeff.checked_mul(*n).ok_or("affine overflow")?,
                Tok::Ident(name) => {
                    if ident.is_some() {
                        return Err(format!(
                            "affine '{s}': product of two identifiers is not affine"
                        ));
                    }
                    ident = Some(name.clone());
                }
                _ => return Err(format!("affine '{s}': expected a number or identifier")),
            }
            pos += 1;
            if pos < toks.len() && toks[pos] == Tok::Star {
                pos += 1;
                if pos >= toks.len() {
                    return Err(format!("affine '{s}': dangling '*'"));
                }
                continue;
            }
            break;
        }
        match ident {
            Some(name) => out.add_term(name.as_str(), sign * coeff),
            None => out = out.add(&LinExpr::constant(sign * coeff)),
        }
        if pos >= toks.len() {
            break;
        }
        if !matches!(toks[pos], Tok::Plus | Tok::Minus) {
            return Err(format!("affine '{s}': expected '+' or '-'"));
        }
    }
    Ok(out)
}

/// An affine field: a string expression or a literal integer.
fn affine_of(j: &Json, what: &str) -> Result<LinExpr, String> {
    if let Json::Str(s) = j {
        return parse_affine(s);
    }
    j.as_i64()
        .map(LinExpr::constant)
        .ok_or_else(|| format!("{what}: expected an affine string or integer, got {j}"))
}

fn int_of(j: &Json, what: &str) -> Result<i64, String> {
    j.as_i64()
        .ok_or_else(|| format!("{what}: expected an integer, got {j}"))
}

fn dtype_of(s: &str) -> Result<DType, String> {
    match s {
        "f32" => Ok(DType::F32),
        "f64" => Ok(DType::F64),
        "f32x4" => Ok(DType::F32x4),
        "i32" => Ok(DType::I32),
        other => Err(format!("unknown dtype '{other}' (f32|f64|f32x4|i32)")),
    }
}

fn tag_of(s: &str) -> Result<IdxTag, String> {
    match s {
        "group0" => Ok(IdxTag::Group(0)),
        "group1" => Ok(IdxTag::Group(1)),
        "local0" => Ok(IdxTag::Local(0)),
        "local1" => Ok(IdxTag::Local(1)),
        "seq" => Ok(IdxTag::Seq),
        "unroll" => Ok(IdxTag::Unroll),
        other => Err(format!(
            "unknown dim tag '{other}' (group0|group1|local0|local1|seq|unroll)"
        )),
    }
}

fn idx_list(j: Option<&Json>, what: &str) -> Result<Vec<LinExpr>, String> {
    j.and_then(Json::as_arr)
        .ok_or_else(|| format!("{what}: missing 'idx' array"))?
        .iter()
        .map(|e| affine_of(e, what))
        .collect()
}

fn expr_of(j: &Json) -> Result<Expr, String> {
    // conveniences: bare numbers are literals, bare strings affine
    match j {
        Json::Num(x) => return Ok(Expr::Lit(*x)),
        Json::Str(s) => return Ok(Expr::Idx(parse_affine(s)?)),
        Json::Obj(m) => {
            if m.len() != 1 {
                return Err(format!(
                    "expression object must have exactly one operative key, got {j}"
                ));
            }
        }
        _ => return Err(format!("bad expression {j}")),
    }
    let (key, v) = match j {
        Json::Obj(m) => match m.iter().next() {
            Some((k, v)) => (k.as_str(), v),
            // m.len() == 1 was checked above
            None => return Err(format!("bad expression {j}")),
        },
        _ => unreachable!(),
    };
    let bin = |op: BinOp, v: &Json| -> Result<Expr, String> {
        let arr = v
            .as_arr()
            .filter(|a| a.len() == 2)
            .ok_or_else(|| format!("'{key}' expects a two-element array"))?;
        Ok(Expr::bin(op, expr_of(&arr[0])?, expr_of(&arr[1])?))
    };
    let un = |op: UnOp, v: &Json| -> Result<Expr, String> { Ok(Expr::un(op, expr_of(v)?)) };
    let red = |op: RedOp, v: &Json| -> Result<Expr, String> {
        let iname = v
            .get_str("iname")
            .ok_or_else(|| format!("'{key}' expects {{\"iname\", \"body\"}}"))?;
        let body = v
            .get("body")
            .ok_or_else(|| format!("'{key}' expects {{\"iname\", \"body\"}}"))?;
        Ok(Expr::Reduce(op, Sym::intern(iname), Box::new(expr_of(body)?)))
    };
    match key {
        "lit" => Ok(Expr::Lit(v.as_f64().ok_or("'lit' expects a number")?)),
        "idx" => Ok(Expr::Idx(affine_of(v, "'idx'")?)),
        "load" => {
            let array = v.get_str("array").ok_or("'load' expects {\"array\", \"idx\"}")?;
            Ok(Expr::Load(Access {
                array: Sym::intern(array),
                idx: idx_list(v.get("idx"), "'load'")?,
            }))
        }
        "add" => bin(BinOp::Add, v),
        "sub" => bin(BinOp::Sub, v),
        "mul" => bin(BinOp::Mul, v),
        "div" => bin(BinOp::Div, v),
        "pow" => bin(BinOp::Pow, v),
        "min" => bin(BinOp::Min, v),
        "max" => bin(BinOp::Max, v),
        "neg" => un(UnOp::Neg, v),
        "sqrt" => un(UnOp::Sqrt, v),
        "rsqrt" => un(UnOp::Rsqrt, v),
        "exp" => un(UnOp::Exp, v),
        "sin" => un(UnOp::Sin, v),
        "cos" => un(UnOp::Cos, v),
        "abs" => un(UnOp::Abs, v),
        "sum" => red(RedOp::Sum, v),
        "rmax" => red(RedOp::Max, v),
        "cast" => {
            let dt = dtype_of(v.get_str("dtype").ok_or("'cast' expects {\"dtype\", \"expr\"}")?)?;
            let inner = v.get("expr").ok_or("'cast' expects {\"dtype\", \"expr\"}")?;
            Ok(Expr::cast(dt, expr_of(inner)?))
        }
        other => Err(format!("unknown expression key '{other}'")),
    }
}

/// Parse a full kernel spec (see module docs) and validate it.
pub fn kernel_from_json(j: &Json) -> Result<Kernel, String> {
    let name = j.get_str("name").unwrap_or("inline").to_string();
    let params: Vec<Sym> = j
        .get("params")
        .and_then(Json::as_arr)
        .ok_or("kernel spec: missing 'params' array")?
        .iter()
        .map(|p| {
            p.as_str()
                .map(Sym::intern)
                .ok_or_else(|| "kernel spec: params must be strings".to_string())
        })
        .collect::<Result<_, _>>()?;

    let mut dims = Vec::new();
    let mut tags: BTreeMap<Sym, IdxTag> = BTreeMap::new();
    for d in j
        .get("dims")
        .and_then(Json::as_arr)
        .ok_or("kernel spec: missing 'dims' array")?
    {
        let iname = d.get_str("iname").ok_or("dim: missing 'iname'")?;
        let hi = affine_of(d.get("hi").ok_or_else(|| format!("dim '{iname}': missing 'hi'"))?,
            &format!("dim '{iname}' hi"))?;
        let tiles = match d.get("tiles") {
            Some(t) => int_of(t, &format!("dim '{iname}' tiles"))?,
            None => 1,
        };
        let step = match d.get("step") {
            Some(t) => int_of(t, &format!("dim '{iname}' step"))?,
            None => 1,
        };
        if tiles < 1 || step < 1 {
            return Err(format!("dim '{iname}': tiles and step must be >= 1"));
        }
        dims.push(Dim {
            name: Sym::intern(iname),
            lo: LinExpr::constant(0),
            hi: CeilDiv::new(hi, tiles),
            step,
        });
        let tag = match d.get("tag") {
            Some(t) => tag_of(t.as_str().ok_or_else(|| format!("dim '{iname}': bad tag"))?)?,
            None => IdxTag::Seq,
        };
        tags.insert(Sym::intern(iname), tag);
    }

    let mut arrays = Vec::new();
    for a in j
        .get("arrays")
        .and_then(Json::as_arr)
        .ok_or("kernel spec: missing 'arrays' array")?
    {
        let aname = a.get_str("name").ok_or("array: missing 'name'")?;
        let shape = a
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("array '{aname}': missing 'shape'"))?
            .iter()
            .map(|s| affine_of(s, &format!("array '{aname}' shape")))
            .collect::<Result<Vec<_>, _>>()?;
        let space = match a.get_str("space").unwrap_or("global") {
            "global" => MemSpace::Global,
            "local" => MemSpace::Local,
            "private" => MemSpace::Private,
            other => {
                return Err(format!(
                    "array '{aname}': unknown space '{other}' (global|local|private)"
                ))
            }
        };
        let layout = match a.get_str("layout").unwrap_or("row") {
            "row" => Layout::RowMajor,
            "col" => Layout::ColMajor,
            other => return Err(format!("array '{aname}': unknown layout '{other}' (row|col)")),
        };
        arrays.push(ArrayDecl {
            name: Sym::intern(aname),
            dtype: dtype_of(a.get_str("dtype").unwrap_or("f32"))?,
            shape,
            space,
            layout,
            is_output: a.get("output").and_then(Json::as_bool).unwrap_or(false),
        });
    }

    let mut insns = Vec::new();
    for (id, ij) in j
        .get("insns")
        .and_then(Json::as_arr)
        .ok_or("kernel spec: missing 'insns' array")?
        .iter()
        .enumerate()
    {
        let store = ij.get_str("store").ok_or_else(|| format!("insn {id}: missing 'store'"))?;
        let within = ij
            .get("within")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("insn {id}: missing 'within' array"))?
            .iter()
            .map(|w| {
                w.as_str()
                    .map(Sym::intern)
                    .ok_or_else(|| format!("insn {id}: 'within' entries must be strings"))
            })
            .collect::<Result<Vec<_>, _>>()?;
        let deps = match ij.get("deps").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|d| int_of(d, &format!("insn {id} deps")).map(|x| x as usize))
                .collect::<Result<Vec<_>, _>>()?,
            None => Vec::new(),
        };
        insns.push(Insn {
            id,
            lhs: Access {
                array: Sym::intern(store),
                idx: idx_list(ij.get("idx"), &format!("insn {id}"))?,
            },
            rhs: expr_of(ij.get("expr").ok_or_else(|| format!("insn {id}: missing 'expr'"))?)?,
            within,
            deps,
            is_update: ij.get("update").and_then(Json::as_bool).unwrap_or(false),
        });
    }

    let k = Kernel { name, params, domain: BoxDomain::new(dims), tags, arrays, insns };
    k.validate()?;
    Ok(k)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::qpoly::env;

    #[test]
    fn affine_parser_basics() {
        let e = parse_affine("256*g0 + l0").unwrap();
        assert_eq!(e.eval(&env(&[("g0", 3), ("l0", 5)])).unwrap(), 773);
        let e = parse_affine("2*n - 1").unwrap();
        assert_eq!(e.eval(&env(&[("n", 10)])).unwrap(), 19);
        let e = parse_affine("-n + 4").unwrap();
        assert_eq!(e.eval(&env(&[("n", 1)])).unwrap(), 3);
        let e = parse_affine("n*3").unwrap();
        assert_eq!(e.eval(&env(&[("n", 2)])).unwrap(), 6);
        assert_eq!(parse_affine("42").unwrap(), LinExpr::constant(42));
        // repeated terms fold
        let e = parse_affine("n + n").unwrap();
        assert_eq!(e.eval(&env(&[("n", 5)])).unwrap(), 10);
    }

    #[test]
    fn affine_parser_rejects_nonaffine() {
        assert!(parse_affine("n*m").is_err());
        assert!(parse_affine("n +").is_err());
        assert!(parse_affine("2 *").is_err());
        assert!(parse_affine("").is_err());
        assert!(parse_affine("n / 2").is_err());
    }

    fn scale_spec() -> Json {
        Json::parse(
            r#"{
                "name": "scale2", "params": ["n"],
                "dims": [
                    {"iname": "g0", "tag": "group0", "hi": "n", "tiles": 256},
                    {"iname": "l0", "tag": "local0", "hi": 256}
                ],
                "arrays": [
                    {"name": "a", "dtype": "f32", "shape": ["n"]},
                    {"name": "b", "dtype": "f32", "shape": ["n"], "output": true}
                ],
                "insns": [
                    {"store": "b", "idx": ["256*g0 + l0"],
                     "expr": {"mul": [{"lit": 2}, {"load": {"array": "a", "idx": ["256*g0 + l0"]}}]},
                     "within": ["g0", "l0"]}
                ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn scale_kernel_parses_and_matches_builder() {
        use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
        let k = kernel_from_json(&scale_spec()).unwrap();
        let built = KernelBuilder::new("scale2", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(256)]),
                Expr::mul(Expr::lit(2.0), Expr::load("a", vec![gid_lin_1d(256)])),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        // structurally identical to the builder-made kernel
        assert_eq!(
            super::super::hash::structural_hash(&k),
            super::super::hash::structural_hash(&built)
        );
        let e = env(&[("n", 1024)]);
        assert_eq!(k.group_count_at(&e).unwrap(), 4);
        assert_eq!(k.group_size_at(&e).unwrap(), (256, 1));
    }

    #[test]
    fn reduction_and_cast_specs_parse() {
        let j = Json::parse(
            r#"{
                "name": "dotk", "params": ["n", "k"],
                "dims": [
                    {"iname": "g0", "tag": "group0", "hi": "n", "tiles": 128},
                    {"iname": "l0", "tag": "local0", "hi": 128},
                    {"iname": "r", "hi": "k"}
                ],
                "arrays": [
                    {"name": "a", "dtype": "f64", "shape": ["n", "k"]},
                    {"name": "o", "dtype": "f64", "shape": ["n"], "output": true}
                ],
                "insns": [
                    {"store": "o", "idx": ["128*g0 + l0"],
                     "expr": {"sum": {"iname": "r",
                        "body": {"cast": {"dtype": "f64", "expr":
                            {"load": {"array": "a", "idx": ["128*g0 + l0", "r"]}}}}}},
                     "within": ["g0", "l0"]}
                ]
            }"#,
        )
        .unwrap();
        let k = kernel_from_json(&j).unwrap();
        assert_eq!(k.insns[0].rhs.reduction_inames(), vec![Sym::intern("r")]);
        let e = env(&[("n", 256), ("k", 8)]);
        assert_eq!(k.insn_domain(&k.insns[0], true).count_at(&e).unwrap(), 2048);
    }

    #[test]
    fn invalid_specs_are_rejected_with_context() {
        // unknown array in an access
        let mut bad = scale_spec();
        if let Json::Obj(m) = &mut bad {
            m.insert(
                "insns".into(),
                Json::parse(
                    r#"[{"store": "nope", "idx": ["l0"], "expr": {"lit": 1}, "within": ["g0", "l0"]}]"#,
                )
                .unwrap(),
            );
        }
        assert!(kernel_from_json(&bad).unwrap_err().contains("nope"));
        // unknown dtype
        let bad = Json::parse(r#"{"params": [], "dims": [], "arrays": [{"name": "a", "dtype": "f16", "shape": [4]}], "insns": []}"#).unwrap();
        assert!(kernel_from_json(&bad).unwrap_err().contains("f16"));
        // ambiguous expression object
        assert!(expr_of(&Json::parse(r#"{"lit": 1, "idx": "n"}"#).unwrap()).is_err());
        // unknown operator
        assert!(expr_of(&Json::parse(r#"{"mod": [1, 2]}"#).unwrap()).is_err());
    }
}
