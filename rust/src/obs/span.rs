//! Structured spans: pay-for-what-you-use request/phase timing with a
//! bounded, lock-sharded ring buffer, slow-root capture, and two
//! export shapes — recent/slow spans as JSON (the service's
//! `{"cmd": "trace"}`) and Chrome trace-event JSON (`--profile`,
//! loadable in `chrome://tracing` / Perfetto).
//!
//! The recorder is process-global and **disabled by default**: every
//! entry point is guarded by one relaxed atomic load
//! ([`enabled`]), and a disabled guard is a no-op carrying no
//! timestamps — so with tracing off the instrumented code paths do no
//! extra work and response bytes stay bit-identical (pinned in
//! `rust/tests/obs.rs`).
//!
//! Nesting uses a thread-local span stack: [`Span::root`] starts a new
//! trace, [`Span::child`] parents under the innermost live span on
//! this thread (falling back to a fresh root when there is none — a
//! worker thread's spans become their own well-formed trees rather
//! than orphans). Guards record on drop, so trees are well-nested by
//! construction: a child's interval closes before its parent's. Roots
//! whose duration reaches the slow threshold are copied into a
//! separate slow ring so a burst of fast traffic cannot evict the
//! evidence of a slow request.

use crate::util::json::Json;
use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// One finished span, as held in the rings and exported.
#[derive(Clone, Debug)]
pub struct SpanRec {
    /// trace id (shared by a whole tree; assigned at the root)
    pub trace: u64,
    /// this span's id (process-unique)
    pub span: u64,
    /// parent span id within the trace (0 = root)
    pub parent: u64,
    pub name: &'static str,
    /// start, µs since the recorder epoch
    pub start_us: u64,
    pub dur_us: u64,
    /// recording thread (dense per-thread ordinal, for trace viewers)
    pub tid: u64,
    /// optional free-form annotation (kernel name, shed reason, …);
    /// borrowed for `&'static str` annotations so the request hot
    /// path records without allocating
    pub meta: Option<Cow<'static, str>>,
}

/// Ring capacity per shard (8 shards → 4096 recent spans held).
const SHARD_CAP: usize = 512;
const SHARDS: usize = 8;
/// Slow-root ring capacity.
const SLOW_CAP: usize = 256;

struct Ring {
    buf: Vec<SpanRec>,
    next: usize,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { buf: Vec::with_capacity(cap), next: 0 }
    }

    fn push(&mut self, rec: SpanRec, cap: usize) {
        if self.buf.len() < cap {
            self.buf.push(rec);
        } else {
            self.buf[self.next] = rec;
            self.next = (self.next + 1) % cap;
        }
    }
}

struct Recorder {
    epoch: Instant,
    shards: Vec<Mutex<Ring>>,
    slow: Mutex<Ring>,
    next_trace: AtomicU64,
    next_span: AtomicU64,
    next_tid: AtomicU64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SLOW_US: AtomicU64 = AtomicU64::new(u64::MAX);
static RECORDER: OnceLock<Recorder> = OnceLock::new();

fn recorder() -> &'static Recorder {
    RECORDER.get_or_init(|| Recorder {
        epoch: Instant::now(),
        shards: (0..SHARDS).map(|_| Mutex::new(Ring::new(SHARD_CAP))).collect(),
        slow: Mutex::new(Ring::new(SLOW_CAP)),
        next_trace: AtomicU64::new(1),
        next_span: AtomicU64::new(1),
        next_tid: AtomicU64::new(1),
    })
}

thread_local! {
    /// (trace, span) of every live guard on this thread, innermost last.
    static STACK: RefCell<Vec<(u64, u64)>> = const { RefCell::new(Vec::new()) };
    static TID: RefCell<u64> = const { RefCell::new(0) };
}

fn thread_ord() -> u64 {
    TID.with(|t| {
        let mut t = t.borrow_mut();
        if *t == 0 {
            *t = recorder().next_tid.fetch_add(1, Ordering::Relaxed);
        }
        *t
    })
}

/// Is span recording on? One relaxed load — the guard every
/// instrumented call site checks first (implicitly, via
/// [`Span::root`]/[`Span::child`] returning a no-op guard).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on with a slow-root threshold in milliseconds
/// (roots at or above it are additionally kept in the slow ring;
/// pass `f64::INFINITY` to keep none).
pub fn enable(slow_ms: f64) {
    let _ = recorder();
    let slow_us = if slow_ms.is_finite() && slow_ms >= 0.0 {
        (slow_ms * 1e3).round() as u64
    } else {
        u64::MAX
    };
    SLOW_US.store(slow_us, Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn recording off (already-recorded spans stay readable).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

fn lock_ring(m: &Mutex<Ring>) -> std::sync::MutexGuard<'_, Ring> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A live span. Created by [`Span::root`]/[`Span::child`]; records
/// itself into the ring on drop. When recording is disabled the guard
/// is inert — no clock read, no allocation, no lock.
pub struct Span {
    live: Option<LiveSpan>,
}

struct LiveSpan {
    trace: u64,
    span: u64,
    parent: u64,
    name: &'static str,
    start: Instant,
    start_us: u64,
    meta: Option<Cow<'static, str>>,
}

impl Span {
    /// Start a root span: a fresh trace id, parent 0. (If this thread
    /// already has a live span, the "root" still starts its own trace
    /// — roots mark request/phase boundaries, never nest.)
    pub fn root(name: &'static str) -> Span {
        if !enabled() {
            return Span { live: None };
        }
        let r = recorder();
        let trace = r.next_trace.fetch_add(1, Ordering::Relaxed);
        Span::start(r, trace, 0, name)
    }

    /// Start a child of the innermost live span on this thread; with
    /// no live span it degrades to a root of its own fresh trace.
    pub fn child(name: &'static str) -> Span {
        if !enabled() {
            return Span { live: None };
        }
        let r = recorder();
        let (trace, parent) = STACK.with(|s| {
            s.borrow().last().copied().unwrap_or((0, 0))
        });
        let trace = if trace == 0 {
            r.next_trace.fetch_add(1, Ordering::Relaxed)
        } else {
            trace
        };
        Span::start(r, trace, parent, name)
    }

    fn start(r: &'static Recorder, trace: u64, parent: u64, name: &'static str) -> Span {
        let span = r.next_span.fetch_add(1, Ordering::Relaxed);
        let start = Instant::now();
        let start_us = start.duration_since(r.epoch).as_micros() as u64;
        STACK.with(|s| s.borrow_mut().push((trace, span)));
        Span {
            live: Some(LiveSpan { trace, span, parent, name, start, start_us, meta: None }),
        }
    }

    /// Attach a free-form annotation (kernel name, shed reason, …).
    /// No-op on an inert guard; `&'static str` annotations are stored
    /// borrowed (no allocation on the hot path).
    pub fn set_meta(&mut self, meta: impl Into<Cow<'static, str>>) {
        if let Some(l) = &mut self.live {
            l.meta = Some(meta.into());
        }
    }

    /// This span's trace id (0 on an inert guard) — lets callers
    /// correlate externally (e.g. a test filtering the ring).
    pub fn trace_id(&self) -> u64 {
        self.live.as_ref().map(|l| l.trace).unwrap_or(0)
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(l) = self.live.take() else { return };
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            // pop our own frame; tolerate out-of-order drops by
            // removing the matching entry instead of blind-popping
            if let Some(pos) = s.iter().rposition(|&(_, id)| id == l.span) {
                s.remove(pos);
            }
        });
        let dur_us = l.start.elapsed().as_micros() as u64;
        let tid = thread_ord();
        let rec = SpanRec {
            trace: l.trace,
            span: l.span,
            parent: l.parent,
            name: l.name,
            start_us: l.start_us,
            dur_us,
            tid,
            meta: l.meta,
        };
        let r = recorder();
        if rec.parent == 0 && dur_us >= SLOW_US.load(Ordering::Relaxed) {
            lock_ring(&r.slow).push(rec.clone(), SLOW_CAP);
        }
        let shard = (tid as usize) % SHARDS;
        lock_ring(&r.shards[shard]).push(rec, SHARD_CAP);
    }
}

/// Non-draining copy of the recent ring, ordered by span id (creation
/// order). Repeatable: two reads with no traffic between them return
/// the same spans.
pub fn recent() -> Vec<SpanRec> {
    let r = recorder();
    let mut out = Vec::new();
    for shard in &r.shards {
        out.extend(lock_ring(shard).buf.iter().cloned());
    }
    out.sort_by_key(|s| s.span);
    out
}

/// Non-draining copy of the slow-root ring, ordered by span id.
pub fn slow() -> Vec<SpanRec> {
    let r = recorder();
    let mut out: Vec<SpanRec> = lock_ring(&r.slow).buf.to_vec();
    out.sort_by_key(|s| s.span);
    out
}

fn span_json(s: &SpanRec) -> Json {
    let mut fields = vec![
        ("trace", Json::Num(s.trace as f64)),
        ("span", Json::Num(s.span as f64)),
        ("parent", Json::Num(s.parent as f64)),
        ("name", Json::Str(s.name.to_string())),
        ("start_us", Json::Num(s.start_us as f64)),
        ("dur_us", Json::Num(s.dur_us as f64)),
        ("tid", Json::Num(s.tid as f64)),
    ];
    if let Some(m) = &s.meta {
        fields.push(("meta", Json::Str(m.to_string())));
    }
    Json::obj(fields)
}

/// The `{"cmd": "trace"}` payload: recording state plus the most
/// recent `limit` spans and every held slow root, as JSON.
pub fn trace_json(limit: usize) -> Json {
    let mut rec = recent();
    if rec.len() > limit {
        rec.drain(..rec.len() - limit);
    }
    Json::obj(vec![
        ("enabled", Json::Bool(enabled())),
        ("spans", Json::Arr(rec.iter().map(span_json).collect())),
        ("slow", Json::Arr(slow().iter().map(span_json).collect())),
    ])
}

/// Render every held span as Chrome trace-event JSON (an array of
/// `ph: "X"` complete events; µs timestamps), the format
/// `chrome://tracing` and Perfetto load directly.
pub fn chrome_trace_json() -> String {
    let spans = recent();
    let mut out = String::from("[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let mut args = BTreeMap::new();
        args.insert("trace".to_string(), Json::Num(s.trace as f64));
        args.insert("span".to_string(), Json::Num(s.span as f64));
        args.insert("parent".to_string(), Json::Num(s.parent as f64));
        if let Some(m) = &s.meta {
            args.insert("meta".to_string(), Json::Str(m.to_string()));
        }
        let ev = Json::obj(vec![
            ("name", Json::Str(s.name.to_string())),
            ("ph", Json::Str("X".to_string())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(s.tid as f64)),
            ("ts", Json::Num(s.start_us as f64)),
            ("dur", Json::Num(s.dur_us as f64)),
            ("args", Json::Obj(args)),
        ]);
        out.push_str(&ev.compact());
    }
    out.push(']');
    out
}

/// Write the Chrome trace to `path` (the `--profile <path>` exit hook).
pub fn write_chrome_trace(path: &std::path::Path) -> Result<(), String> {
    std::fs::write(path, chrome_trace_json())
        .map_err(|e| format!("profile {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests only ever *enable* the recorder (never disable) and filter
    // by their own trace ids, so they compose with any parallel test
    // in this binary that also records spans.

    #[test]
    fn disabled_guards_are_inert() {
        // default state is disabled unless another test enabled first;
        // force the known state locally via a scoped check
        if !enabled() {
            let mut s = Span::root("inert");
            s.set_meta("x");
            assert_eq!(s.trace_id(), 0);
            drop(s);
        }
    }

    #[test]
    fn trees_are_well_nested_and_filterable_by_trace() {
        enable(f64::INFINITY);
        let trace = {
            let root = Span::root("request");
            let t = root.trace_id();
            {
                let mut c = Span::child("parse");
                c.set_meta("k=fd5");
                let _g = Span::child("render");
            }
            t
        };
        assert!(trace > 0);
        let mine: Vec<SpanRec> =
            recent().into_iter().filter(|s| s.trace == trace).collect();
        assert_eq!(mine.len(), 3);
        let root = mine.iter().find(|s| s.parent == 0).expect("root");
        assert_eq!(root.name, "request");
        for s in &mine {
            if s.span != root.span {
                // children parent under the root or under the parse child
                assert!(mine.iter().any(|p| p.span == s.parent), "orphan {s:?}");
                // well-nested: child interval within the parent's
                let p = mine.iter().find(|p| p.span == s.parent).expect("parent");
                assert!(s.start_us >= p.start_us);
                assert!(s.start_us + s.dur_us <= p.start_us + p.dur_us + 1);
            }
        }
        let parse = mine.iter().find(|s| s.name == "parse").expect("parse span");
        assert_eq!(parse.meta.as_deref(), Some("k=fd5"));
    }

    #[test]
    fn slow_roots_are_captured_separately() {
        enable(0.0); // every root is "slow" at a 0 ms threshold
        let t = {
            let r = Span::root("slowreq");
            r.trace_id()
        };
        let got: Vec<SpanRec> = slow().into_iter().filter(|s| s.trace == t).collect();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].name, "slowreq");
        // restore an effectively-off threshold for sibling tests
        enable(f64::INFINITY);
    }

    #[test]
    fn chrome_export_is_loadable_json() {
        enable(f64::INFINITY);
        let _t = {
            let _r = Span::root("phase");
            let _c = Span::child("step");
        };
        let text = chrome_trace_json();
        let j = Json::parse(&text).expect("chrome trace must parse");
        match j {
            Json::Arr(events) => {
                assert!(!events.is_empty());
                for e in &events {
                    assert_eq!(e.get_str("ph"), Some("X"));
                    assert!(e.get_f64("ts").is_some());
                    assert!(e.get_f64("dur").is_some());
                }
            }
            _ => panic!("chrome trace must be a JSON array"),
        }
    }

    #[test]
    fn trace_json_shape() {
        enable(f64::INFINITY);
        let _t = {
            let _r = Span::root("req");
        };
        let j = trace_json(16);
        assert_eq!(j.get("enabled").and_then(crate::util::json::Json::as_bool), Some(true));
        assert!(matches!(j.get("spans"), Some(Json::Arr(_))));
        assert!(matches!(j.get("slow"), Some(Json::Arr(_))));
    }
}
