//! `obs` — the unified observability plane: a typed metrics registry
//! ([`metrics`]), structured spans with bounded ring capture and
//! Chrome-trace export ([`span`]), and a leveled stderr logger
//! ([`log`], via the crate-wide `olog!` macro).
//!
//! Design contract (see DESIGN.md § Observability):
//!
//! * **Pay for what you use.** Span recording is off by default behind
//!   one relaxed atomic load; metric updates are single relaxed
//!   atomics — the same cost as the ad-hoc counters they replaced.
//!   With tracing disabled, response bytes on every serving path are
//!   bit-identical to the uninstrumented binary (pinned in
//!   `rust/tests/obs.rs`; overhead with tracing *enabled* is gated
//!   ≤ 3% by `benches/obs.rs`).
//! * **One snapshot, three surfaces.** The service assembles a single
//!   [`metrics::Snapshot`] (registry + cache + engine + fault
//!   counters) and feeds the *same* snapshot to `{"cmd": "health"}`,
//!   `{"cmd": "stats"}`/`ServiceSummary` and the Prometheus-style
//!   `{"cmd": "metrics"}` exposition — the surfaces cannot disagree.
//! * **Deterministic semantics.** Snapshots are name-ordered; merges
//!   are order-independent (counters/histograms add, gauges max);
//!   histogram quantiles are exact within a log₂ bucket.

pub mod log;
pub mod metrics;
pub mod span;

pub use metrics::{Counter, Gauge, Histogram, HistogramSnapshot, MetricValue, Registry, Snapshot};
pub use span::Span;
