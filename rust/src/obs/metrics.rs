//! The typed metrics registry: named counters, gauges and fixed-bucket
//! log₂ histograms with deterministic snapshot/merge semantics and a
//! Prometheus-style text exposition.
//!
//! Everything here is a thin veneer over `AtomicU64`, so the hot-path
//! cost of a metric update is one relaxed atomic op — the same cost as
//! the ad-hoc counters this module replaced across `service`,
//! `service::cache` and `util::fault`. The registry itself
//! (name → handle map) is only locked at registration and snapshot
//! time; recording paths hold pre-registered `Arc` handles and never
//! touch the map.
//!
//! Histograms use 65 fixed log₂ buckets over non-negative integer
//! values (bucket `b` holds `[2^(b-1), 2^b)`; bucket 0 holds exactly
//! 0), each bucket keeping a count *and* a sum. Quantiles return the
//! **mean of the bucket the quantile rank lands in**: error is bounded
//! by the bucket width (a factor of 2 in the value), and a population
//! whose samples all share one bucket reports that bucket's exact mean
//! — so e.g. a batch-width histogram fed nothing but 4s answers
//! p50 = p99 = mean = 4 exactly, which is what lets the service tests
//! pin exact values instead of tolerances.
//!
//! Merge semantics (deterministic, order-independent for counters and
//! histograms): counters add, histograms add bucketwise, gauges keep
//! the maximum — merging N worker snapshots equals one snapshot of the
//! combined stream for the additive kinds, and the gauge rule is the
//! only associative-commutative choice that never invents a value
//! neither side observed.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Number of histogram buckets: one for zero plus one per power of two
/// up to `2^64`.
pub const BUCKETS: usize = 65;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Atomically increment and return the *previous* value — the
    /// claim-a-slot primitive counter-based decision streams need
    /// (`util::fault`'s per-site attempt index must be race-free to
    /// stay deterministic under concurrent queries).
    #[inline]
    pub fn next(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed)
    }

    /// Decrement — for the rare "reserve then back out" accounting
    /// pattern (e.g. a fault-injection ceiling race).
    #[inline]
    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-written-value gauge (queue depths, configured capacities).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket index for a value: 0 for 0, else `floor(log2 v) + 1` — so
/// bucket `b ≥ 1` covers `[2^(b-1), 2^b)`.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros()) as usize
    }
}

/// A fixed-bucket log₂ histogram over non-negative integer samples
/// (the service feeds it microseconds and batch widths). Per-bucket
/// count **and** sum, so quantiles are exact within their bucket.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sums: [AtomicU64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sums: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        let b = bucket_of(v);
        self.counts[b].fetch_add(1, Ordering::Relaxed);
        self.sums[b].fetch_add(v, Ordering::Relaxed);
    }

    /// Observe a non-negative float sample, rounded to the nearest
    /// integer (negative or non-finite samples clamp to 0).
    #[inline]
    pub fn observe_f64(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v.round() as u64 } else { 0 };
        self.observe(v);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            sums: self.sums.iter().map(|s| s.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// A point-in-time copy of a [`Histogram`]'s buckets: the unit of
/// percentile computation, merging and exposition.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub counts: Vec<u64>,
    pub sums: Vec<u64>,
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn sum(&self) -> u64 {
        self.sums.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// The q-quantile (`q` in `[0, 1]`): nearest-rank over the bucket
    /// counts, answering the **mean of the bucket the rank lands in**.
    /// Exact when the population shares one bucket; otherwise within a
    /// factor of 2 (the bucket width).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= rank {
                return self.sums[b] as f64 / c as f64;
            }
        }
        0.0
    }

    /// Bucketwise addition (the histogram merge rule).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
            self.sums.resize(other.sums.len(), 0);
        }
        for (i, &c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        for (i, &s) in other.sums.iter().enumerate() {
            self.sums[i] += s;
        }
    }
}

/// One metric's value inside a [`Snapshot`].
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(u64),
    Histogram(HistogramSnapshot),
}

/// A deterministic point-in-time view of a registry (plus any
/// synthetic entries a caller folds in): name-ordered, mergeable, and
/// renderable as Prometheus-style text.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    values: BTreeMap<String, MetricValue>,
}

impl Snapshot {
    pub fn new() -> Snapshot {
        Snapshot { values: BTreeMap::new() }
    }

    pub fn set_counter(&mut self, name: &str, v: u64) {
        self.values.insert(name.to_string(), MetricValue::Counter(v));
    }

    pub fn set_gauge(&mut self, name: &str, v: u64) {
        self.values.insert(name.to_string(), MetricValue::Gauge(v));
    }

    pub fn set_histogram(&mut self, name: &str, h: HistogramSnapshot) {
        self.values.insert(name.to_string(), MetricValue::Histogram(h));
    }

    pub fn counter(&self, name: &str) -> u64 {
        match self.values.get(name) {
            Some(MetricValue::Counter(v)) => *v,
            _ => 0,
        }
    }

    pub fn gauge(&self, name: &str) -> u64 {
        match self.values.get(name) {
            Some(MetricValue::Gauge(v)) => *v,
            _ => 0,
        }
    }

    pub fn histogram(&self, name: &str) -> HistogramSnapshot {
        match self.values.get(name) {
            Some(MetricValue::Histogram(h)) => h.clone(),
            _ => HistogramSnapshot::default(),
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &MetricValue)> {
        self.values.iter()
    }

    /// Merge `other` into `self`: counters add, histograms add
    /// bucketwise, gauges keep the maximum. Entries of mismatched kind
    /// keep `self`'s value (a schema conflict, not a data race — the
    /// deterministic choice is to not guess).
    pub fn merge(&mut self, other: &Snapshot) {
        for (name, v) in &other.values {
            match (self.values.get_mut(name), v) {
                (None, v) => {
                    self.values.insert(name.clone(), v.clone());
                }
                (Some(MetricValue::Counter(a)), MetricValue::Counter(b)) => *a += b,
                (Some(MetricValue::Gauge(a)), MetricValue::Gauge(b)) => *a = (*a).max(*b),
                (Some(MetricValue::Histogram(a)), MetricValue::Histogram(b)) => a.merge(b),
                _ => {}
            }
        }
    }

    /// Prometheus-style text exposition. Every metric name is prefixed
    /// `uniperf_`; histograms render cumulative `_bucket{le="..."}`
    /// lines (powers of two, only up to the highest populated bucket)
    /// plus `_sum`/`_count`. Labeled series (`name{label="x"}`) share
    /// one `# TYPE` line per family — name ordering keeps a family's
    /// series adjacent. Deterministic for a given snapshot:
    /// name-ordered, fixed formatting.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_family = String::new();
        for (name, v) in &self.values {
            let full = format!("uniperf_{name}");
            // the family is the name up to the label set; unlabeled
            // names are their own family, so their TYPE lines render
            // exactly as before
            let family = match full.split_once('{') {
                Some((fam, _)) => fam.to_string(),
                None => full.clone(),
            };
            let kind = match v {
                MetricValue::Counter(_) => "counter",
                MetricValue::Gauge(_) => "gauge",
                MetricValue::Histogram(_) => "histogram",
            };
            if family != last_family {
                out.push_str(&format!("# TYPE {family} {kind}\n"));
                last_family = family;
            }
            match v {
                MetricValue::Counter(c) => {
                    out.push_str(&format!("{full} {c}\n"));
                }
                MetricValue::Gauge(g) => {
                    out.push_str(&format!("{full} {g}\n"));
                }
                MetricValue::Histogram(h) => {
                    let mut cum = 0u64;
                    let top = h
                        .counts
                        .iter()
                        .rposition(|&c| c > 0)
                        .map(|b| b + 1)
                        .unwrap_or(0);
                    for (b, &c) in h.counts.iter().enumerate().take(top) {
                        cum += c;
                        // bucket b holds values < 2^b (bucket 0: value 0)
                        let le = if b == 0 {
                            "0".to_string()
                        } else if b >= 64 {
                            continue; // folded into +Inf below
                        } else {
                            (1u64 << b).to_string()
                        };
                        out.push_str(&format!("{full}_bucket{{le=\"{le}\"}} {cum}\n"));
                    }
                    out.push_str(&format!(
                        "{full}_bucket{{le=\"+Inf\"}} {}\n{full}_sum {}\n{full}_count {}\n",
                        h.count(),
                        h.sum(),
                        h.count()
                    ));
                }
            }
        }
        out
    }
}

/// The process-global campaign-plane registry: fit/crossval/transfer
/// counters (per-device `campaign_cases_total{device="..."}`,
/// measurement-cache `meascache_{hits,misses,refused}_total`) recorded
/// from the harness and engine, which have no per-service registry to
/// hand counters to. The service merges this snapshot into its
/// `{"cmd": "metrics"}` response. Lazily populated: a process that
/// never measures registers nothing here, so a pure serving process's
/// exposition stays byte-identical.
pub fn campaign() -> &'static Registry {
    static CAMPAIGN: OnceLock<Registry> = OnceLock::new();
    CAMPAIGN.get_or_init(Registry::new)
}

/// A registered metric handle (what the registry's map holds).
#[derive(Clone, Debug)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// The typed registry: get-or-register by name, snapshot on demand.
/// Recording paths hold the returned `Arc` handles; the internal map
/// lock is touched only at registration and snapshot time.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

fn locked(m: &Mutex<BTreeMap<String, Metric>>) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-register the counter `name`. A name already registered
    /// as a different kind yields a fresh detached handle (recorded
    /// values go nowhere) — a programming error surfaced as silence
    /// rather than a serving-path panic.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = locked(&self.metrics);
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => Arc::new(Counter::new()),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = locked(&self.metrics);
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            _ => Arc::new(Gauge::new()),
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = locked(&self.metrics);
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())))
        {
            Metric::Histogram(h) => Arc::clone(h),
            _ => Arc::new(Histogram::new()),
        }
    }

    /// Point-in-time view of every registered metric, name-ordered.
    pub fn snapshot(&self) -> Snapshot {
        let m = locked(&self.metrics);
        let mut snap = Snapshot::new();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => snap.set_counter(name, c.get()),
                Metric::Gauge(g) => snap.set_gauge(name, g.get()),
                Metric::Histogram(h) => snap.set_histogram(name, h.snapshot()),
            }
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(7), 3);
        assert_eq!(bucket_of(8), 4);
        assert_eq!(bucket_of(u64::MAX), 64);
    }

    #[test]
    fn single_bucket_population_is_exact() {
        let h = Histogram::new();
        for _ in 0..7 {
            h.observe(4);
        }
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 4.0);
        assert_eq!(s.quantile(0.99), 4.0);
        assert_eq!(s.mean(), 4.0);
        assert_eq!(s.count(), 7);
    }

    #[test]
    fn quantiles_are_within_bucket_means() {
        let h = Histogram::new();
        // 90 samples at 10 (bucket [8,16)), 10 at 1000 (bucket [512,1024))
        for _ in 0..90 {
            h.observe(10);
        }
        for _ in 0..10 {
            h.observe(1000);
        }
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 10.0);
        assert_eq!(s.quantile(0.9), 10.0);
        assert_eq!(s.quantile(0.99), 1000.0);
        assert!((s.mean() - 109.0).abs() < 1e-9);
    }

    #[test]
    fn quantile_error_is_bounded_by_bucket_width() {
        let h = Histogram::new();
        // mixed values inside the [64,128) bucket: the quantile is the
        // bucket mean, within a factor of 2 of any true member
        for v in [65u64, 70, 100, 127] {
            h.observe(v);
        }
        let s = h.snapshot();
        let q = s.quantile(0.5);
        assert!(q >= 64.0 && q < 128.0, "{q}");
        assert_eq!(q, (65.0 + 70.0 + 100.0 + 127.0) / 4.0);
    }

    #[test]
    fn merge_is_additive_for_counters_and_histograms_max_for_gauges() {
        let mut a = Snapshot::new();
        a.set_counter("req", 3);
        a.set_gauge("depth", 5);
        let ha = {
            let h = Histogram::new();
            h.observe(4);
            h.snapshot()
        };
        a.set_histogram("lat", ha);

        let mut b = Snapshot::new();
        b.set_counter("req", 2);
        b.set_gauge("depth", 2);
        b.set_counter("other", 1);
        let hb = {
            let h = Histogram::new();
            h.observe(4);
            h.observe(16);
            h.snapshot()
        };
        b.set_histogram("lat", hb);

        a.merge(&b);
        assert_eq!(a.counter("req"), 5);
        assert_eq!(a.counter("other"), 1);
        assert_eq!(a.gauge("depth"), 5);
        let h = a.histogram("lat");
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 24);
        // merged == one histogram of the combined stream
        let all = Histogram::new();
        for v in [4u64, 4, 16] {
            all.observe(v);
        }
        assert_eq!(h, all.snapshot());
    }

    #[test]
    fn registry_hands_out_shared_handles_and_snapshots_deterministically() {
        let r = Registry::new();
        let c1 = r.counter("requests_total");
        let c2 = r.counter("requests_total");
        c1.inc();
        c2.add(2);
        assert_eq!(c1.get(), 3);
        r.gauge("queue_depth").set(7);
        r.histogram("latency_us").observe(100);
        let s1 = r.snapshot();
        let s2 = r.snapshot();
        assert_eq!(s1, s2);
        assert_eq!(s1.counter("requests_total"), 3);
        assert_eq!(s1.gauge("queue_depth"), 7);
        assert_eq!(s1.histogram("latency_us").count(), 1);
        // kind mismatch: detached handle, registered value untouched
        let detached = r.gauge("requests_total");
        detached.set(99);
        assert_eq!(r.snapshot().counter("requests_total"), 3);
    }

    #[test]
    fn prometheus_exposition_is_deterministic_text() {
        let r = Registry::new();
        r.counter("requests_total").add(3);
        r.gauge("queue_depth").set(2);
        let h = r.histogram("latency_us");
        h.observe(0);
        h.observe(5);
        h.observe(5);
        h.observe(100);
        let text = r.snapshot().render_prometheus();
        let want = "\
# TYPE uniperf_latency_us histogram
uniperf_latency_us_bucket{le=\"0\"} 1
uniperf_latency_us_bucket{le=\"2\"} 1
uniperf_latency_us_bucket{le=\"4\"} 1
uniperf_latency_us_bucket{le=\"8\"} 3
uniperf_latency_us_bucket{le=\"16\"} 3
uniperf_latency_us_bucket{le=\"32\"} 3
uniperf_latency_us_bucket{le=\"64\"} 3
uniperf_latency_us_bucket{le=\"128\"} 4
uniperf_latency_us_bucket{le=\"+Inf\"} 4
uniperf_latency_us_sum 110
uniperf_latency_us_count 4
# TYPE uniperf_queue_depth gauge
uniperf_queue_depth 2
# TYPE uniperf_requests_total counter
uniperf_requests_total 3
";
        assert_eq!(text, want);
    }

    /// Labeled series (the campaign plane's per-device counters) render
    /// one `# TYPE` line per family, not one per series — name ordering
    /// keeps a family's series adjacent.
    #[test]
    fn labeled_series_share_one_type_line_per_family() {
        let r = Registry::new();
        r.counter("campaign_cases_total{device=\"k40c\"}").add(3);
        r.counter("campaign_cases_total{device=\"r9_fury\"}").add(2);
        r.counter("meascache_hits_total").add(7);
        let text = r.snapshot().render_prometheus();
        let want = "\
# TYPE uniperf_campaign_cases_total counter
uniperf_campaign_cases_total{device=\"k40c\"} 3
uniperf_campaign_cases_total{device=\"r9_fury\"} 2
# TYPE uniperf_meascache_hits_total counter
uniperf_meascache_hits_total 7
";
        assert_eq!(text, want);
    }
}
