//! A minimal leveled stderr logger: every non-test diagnostic line in
//! the crate routes through here (the `olog!` macro) instead of bare
//! `eprintln!`, so `--log-level` gates verbosity uniformly.
//!
//! Message *bytes* are unchanged from the historical `eprintln!` lines
//! — [`emit`] prints exactly the formatted message — so at the default
//! level (`info`) stderr output is identical to the pre-logger
//! binary. The level check happens **before** formatting (see
//! `olog!`), so a suppressed line costs one relaxed atomic load and
//! never allocates. Call-site rate limiting (the per-errno accept-log
//! window in `service`) composes in front: the limiter decides
//! *whether* there is a message, the logger decides whether its level
//! prints.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    fn rank(self) -> u8 {
        match self {
            Level::Error => 1,
            Level::Warn => 2,
            Level::Info => 3,
            Level::Debug => 4,
        }
    }
}

/// Highest rank that prints; 0 = off. Default prints error/warn/info —
/// exactly the set of lines the crate emitted before the logger.
static MAX_RANK: AtomicU8 = AtomicU8::new(3);

/// Is `level` currently printed? One relaxed load.
#[inline]
pub fn enabled(level: Level) -> bool {
    level.rank() <= MAX_RANK.load(Ordering::Relaxed)
}

/// Set the threshold: everything at or above `level` severity prints.
pub fn set_level(level: Level) {
    MAX_RANK.store(level.rank(), Ordering::Relaxed);
}

/// Silence everything (the `--log-level off` setting).
pub fn set_off() {
    MAX_RANK.store(0, Ordering::Relaxed);
}

/// Parse a `--log-level` value.
pub fn set_level_str(s: &str) -> Result<(), String> {
    match s {
        "error" => set_level(Level::Error),
        "warn" => set_level(Level::Warn),
        "info" => set_level(Level::Info),
        "debug" => set_level(Level::Debug),
        "off" => set_off(),
        other => {
            return Err(format!(
                "unknown log level '{other}' (use error|warn|info|debug|off)"
            ))
        }
    }
    Ok(())
}

/// Print one already-formatted message to stderr. Callers go through
/// `olog!`, which checks [`enabled`] before formatting.
pub fn emit(_level: Level, msg: &str) {
    eprintln!("{msg}");
}

/// Leveled logging: `olog!(Level::Warn, "uniperf serve: {e}")`. The
/// level gate runs before the format, so suppressed lines never
/// allocate.
#[macro_export]
macro_rules! olog {
    ($lvl:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($lvl) {
            $crate::obs::log::emit($lvl, &format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the level is process-global, so a single test exercises
    // the whole surface (parallel tests must not race the level) and
    // restores the default before returning.

    #[test]
    fn levels_gate_and_parse() {
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));

        set_level_str("debug").unwrap();
        assert!(enabled(Level::Debug));
        set_level_str("error").unwrap();
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        set_level_str("off").unwrap();
        assert!(!enabled(Level::Error));
        assert!(set_level_str("loud").is_err());
        set_level_str("warn").unwrap();
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));

        set_level(Level::Info);
    }
}
