//! Arithmetic-progression helpers for the footprint / utilization-ratio
//! analysis (paper §2.1).
//!
//! An axis-0 access pattern is a union of arithmetic progressions
//! `{ s·i + r : 0 <= i < N }` sharing a stride `s` but differing in
//! residue `r`. The *accessed* cell count is the union size; the *filled*
//! footprint closes the striding gaps. Their ratio is the utilization
//! ratio that the paper quantizes into the amortized-stride-fraction
//! classes.

use std::collections::BTreeSet;

/// A union of arithmetic progressions with a common stride.
#[derive(Clone, Debug, Default)]
pub struct ProgressionUnion {
    /// common stride (cells); 0 = uniform (lane-independent) access
    pub stride: i64,
    /// residues modulo `stride` that are touched (for stride >= 1)
    pub residues: BTreeSet<i64>,
}

impl ProgressionUnion {
    pub fn uniform() -> Self {
        ProgressionUnion { stride: 0, residues: BTreeSet::new() }
    }

    pub fn new(stride: i64) -> Self {
        assert!(stride >= 1);
        ProgressionUnion { stride, residues: BTreeSet::new() }
    }

    pub fn add_offset(&mut self, offset: i64) {
        if self.stride >= 1 {
            self.residues.insert(offset.rem_euclid(self.stride));
        }
    }

    /// Number of residues covered per period of the stride. For stride 0
    /// or 1 this is 1 by convention.
    pub fn covered_per_period(&self) -> i64 {
        if self.stride <= 1 {
            1
        } else {
            (self.residues.len() as i64).clamp(1, self.stride)
        }
    }

    /// Utilization ratio: accessed cells / filled footprint, in the limit
    /// of a long progression (the per-period view the paper quantizes).
    pub fn utilization(&self) -> f64 {
        if self.stride <= 1 {
            1.0
        } else {
            self.covered_per_period() as f64 / self.stride as f64
        }
    }
}

/// The paper's amortized-stride-fraction classes (§2.1). `numer` counts
/// covered cells per period (quantized utilization), `denom_class` the
/// stride with everything above 4 collapsed to ">4".
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StrideClass {
    /// stride 0 — uniform (lane-independent) access
    Uniform,
    /// stride 1 — perfectly coalesced
    Unit,
    /// amortized fraction numer/denom with denom in {2,3,4}
    Frac { numer: u8, denom: u8 },
    /// stride > 4: numer/">4" with numer clamped to 1..=4
    FracGt4 { numer: u8 },
}

impl StrideClass {
    /// Classify an axis-0 access pattern per the paper's rules:
    /// * stride 0 -> `Uniform`, stride 1 -> `Unit` (ratio disregarded);
    /// * stride 2: utilization <= 50% -> 1/2 else 2/2;
    /// * strides 3 and 4: numerator = covered cells per period;
    /// * stride > 4: numerator clamped to 1..=4, denominator ">4".
    pub fn classify(stride: i64, covered_per_period: i64) -> StrideClass {
        match stride {
            0 => StrideClass::Uniform,
            1 => StrideClass::Unit,
            2 => {
                if covered_per_period <= 1 {
                    StrideClass::Frac { numer: 1, denom: 2 }
                } else {
                    StrideClass::Frac { numer: 2, denom: 2 }
                }
            }
            3 | 4 => StrideClass::Frac {
                numer: covered_per_period.clamp(1, stride) as u8,
                denom: stride as u8,
            },
            s if s > 4 => StrideClass::FracGt4 { numer: covered_per_period.clamp(1, 4) as u8 },
            s => {
                // negative stride: same traffic pattern as its magnitude
                StrideClass::classify(-s, covered_per_period)
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            StrideClass::Uniform => "stride-0".into(),
            StrideClass::Unit => "stride-1".into(),
            StrideClass::Frac { numer, denom } => format!("{numer}/{denom}"),
            StrideClass::FracGt4 { numer } => format!("{numer}/>4"),
        }
    }

    /// All classes, in a stable order (used to build the property schema).
    pub fn all() -> Vec<StrideClass> {
        let mut v = vec![StrideClass::Uniform, StrideClass::Unit];
        for denom in 2..=4u8 {
            for numer in 1..=denom {
                v.push(StrideClass::Frac { numer, denom });
            }
        }
        for numer in 1..=4u8 {
            v.push(StrideClass::FracGt4 { numer });
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_of_both_phases_full_utilization() {
        // a[2i] and a[2i+1]: stride 2, both residues -> 2/2
        let mut u = ProgressionUnion::new(2);
        u.add_offset(0);
        u.add_offset(1);
        assert_eq!(u.covered_per_period(), 2);
        assert_eq!(
            StrideClass::classify(2, u.covered_per_period()),
            StrideClass::Frac { numer: 2, denom: 2 }
        );
    }

    #[test]
    fn single_phase_stride2_half() {
        let mut u = ProgressionUnion::new(2);
        u.add_offset(0);
        assert_eq!(u.utilization(), 0.5);
        assert_eq!(
            StrideClass::classify(2, u.covered_per_period()),
            StrideClass::Frac { numer: 1, denom: 2 }
        );
    }

    #[test]
    fn offsets_reduce_modulo_stride() {
        let mut u = ProgressionUnion::new(3);
        u.add_offset(0);
        u.add_offset(3); // same residue
        u.add_offset(7); // residue 1
        assert_eq!(u.covered_per_period(), 2);
    }

    #[test]
    fn stride_gt4_clamps() {
        assert_eq!(StrideClass::classify(9, 1), StrideClass::FracGt4 { numer: 1 });
        assert_eq!(StrideClass::classify(100, 77), StrideClass::FracGt4 { numer: 4 });
    }

    #[test]
    fn uniform_and_unit() {
        assert_eq!(StrideClass::classify(0, 1), StrideClass::Uniform);
        assert_eq!(StrideClass::classify(1, 1), StrideClass::Unit);
        // negative stride behaves like its magnitude
        assert_eq!(StrideClass::classify(-1, 1), StrideClass::Unit);
        assert_eq!(StrideClass::classify(-3, 3), StrideClass::Frac { numer: 3, denom: 3 });
    }

    #[test]
    fn all_classes_distinct_labels() {
        let all = StrideClass::all();
        let labels: std::collections::BTreeSet<String> =
            all.iter().map(|c| c.label()).collect();
        assert_eq!(labels.len(), all.len());
        assert_eq!(all.len(), 2 + (2 + 3 + 4) + 4); // uniform, unit, fracs, >4
    }
}
