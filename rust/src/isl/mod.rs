//! Integer-set counting — the stand-in for isl + barvinok (paper §3.2).
//!
//! The basic primitive is counting the integer points of a parametric set,
//! producing a piecewise quasi-polynomial ([`crate::qpoly::PwQPoly`]) in
//! the size parameters. Two paths are provided, mirroring the paper
//! (which uses barvinok "with a fallback to a less accurate, simpler
//! counting technique"):
//!
//! * [`BoxDomain`] — the symbolic fast path: rectangular (possibly strided
//!   and tiled) loop domains, which covers every measurement and test
//!   kernel in the paper. Counts are exact piecewise quasi-polynomials.
//! * [`Set`] — general disjunctions of conjunctions of affine constraints,
//!   counted by enumeration at a concrete parameter binding (the
//!   fallback path; exact but not symbolic).
//!
//! The module also provides arithmetic-progression counting helpers used
//! by the footprint analysis ([`progression`]).

use crate::qpoly::{Atom, Guard, LinExpr, PwQPoly, QPoly};
use crate::util::intern::{Env, Sym};

pub mod progression;

/// Upper bound of a loop dimension: `ceil(num / den)` (exclusive).
/// `den == 1` is the common affine case.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CeilDiv {
    pub num: LinExpr,
    pub den: i64,
}

impl CeilDiv {
    pub fn affine(e: LinExpr) -> CeilDiv {
        CeilDiv { num: e, den: 1 }
    }

    pub fn new(num: LinExpr, den: i64) -> CeilDiv {
        assert!(den >= 1, "denominator must be positive");
        CeilDiv { num, den }
    }

    pub fn eval(&self, env: &Env) -> Result<i64, String> {
        let n = self.num.eval(env)?;
        Ok(div_ceil(n, self.den))
    }

    /// Symbolic value as a quasi-polynomial: `ceil(num/den) =
    /// floor((num + den - 1)/den)`.
    pub fn as_qpoly(&self) -> QPoly {
        if self.den == 1 {
            QPoly::from_lin(&self.num)
        } else {
            let shifted = self.num.add(&LinExpr::constant(self.den - 1));
            QPoly::from_atom(Atom::FloorDiv(shifted, self.den))
        }
    }
}

#[inline]
pub fn div_ceil(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    a.div_euclid(b) + if a.rem_euclid(b) != 0 { 1 } else { 0 }
}

/// One dimension of a rectangular loop domain:
/// `{ lo + step*t : 0 <= t, lo + step*t < hi }` (so trip count
/// `ceil((hi - lo)/step)` with `hi = ceil(num/den)`).
#[derive(Clone, Debug, PartialEq)]
pub struct Dim {
    pub name: Sym,
    /// inclusive lower bound (affine in parameters)
    pub lo: LinExpr,
    /// exclusive upper bound, possibly a ceil-division (tile counts)
    pub hi: CeilDiv,
    /// stride between consecutive iterations (>= 1)
    pub step: i64,
}

impl Dim {
    /// `0 <= name < hi`, step 1.
    pub fn simple(name: &str, hi: LinExpr) -> Dim {
        Dim { name: Sym::intern(name), lo: LinExpr::constant(0), hi: CeilDiv::affine(hi), step: 1 }
    }

    /// `0 <= name < ceil(num/den)`, step 1 — tile loops.
    pub fn tiles(name: &str, num: LinExpr, den: i64) -> Dim {
        assert!(den >= 1);
        Dim { name: Sym::intern(name), lo: LinExpr::constant(0), hi: CeilDiv::new(num, den), step: 1 }
    }

    /// `0 <= name < hi` visiting every `step`-th point — strided loops.
    pub fn strided(name: &str, hi: LinExpr, step: i64) -> Dim {
        assert!(step >= 1);
        Dim { name: Sym::intern(name), lo: LinExpr::constant(0), hi: CeilDiv::affine(hi), step }
    }

    /// Symbolic trip count.
    pub fn trip_count(&self) -> QPoly {
        if self.den_is_simple() {
            // ceil((hi - lo)/step) with affine hi
            let extent = self.hi.num.sub(&self.lo);
            if self.step == 1 {
                QPoly::from_lin(&extent)
            } else {
                let shifted = extent.add(&LinExpr::constant(self.step - 1));
                QPoly::from_atom(Atom::FloorDiv(shifted, self.step))
            }
        } else {
            // hi is a ceil-division: builder enforces lo = 0.
            assert!(
                self.lo.is_constant() && self.lo.c == 0,
                "ceil-div upper bounds require a zero lower bound (dim '{}')",
                self.name
            );
            if self.step == 1 {
                self.hi.as_qpoly()
            } else {
                // trip = ceil(ceil(num/den)/step) = ceil(num/(den*step))
                let den = self.den() * self.step;
                let shifted = self.hi.num.add(&LinExpr::constant(den - 1));
                QPoly::from_atom(Atom::FloorDiv(shifted, den))
            }
        }
    }

    /// Guard `trip >= 1`, i.e. `hi - lo - 1 >= 0` (affine case only; the
    /// ceil-div case uses `num - den*lo - 1 >= 0` which is equivalent for
    /// positive denominators).
    pub fn nonempty_guard(&self) -> Guard {
        if self.den_is_simple() {
            Guard(self.hi.num.sub(&self.lo).sub(&LinExpr::constant(1)))
        } else {
            Guard(self.hi.num.sub(&self.lo.scale(self.den())).sub(&LinExpr::constant(1)))
        }
    }

    fn den(&self) -> i64 {
        self.hi.den
    }

    fn den_is_simple(&self) -> bool {
        self.hi.den == 1
    }

    /// Concrete trip count.
    pub fn trip_count_at(&self, env: &Env) -> Result<i64, String> {
        let hi = self.hi.eval(env)?;
        let lo = self.lo.eval(env)?;
        Ok((div_ceil(hi - lo, self.step)).max(0))
    }
}

/// Rectangular parametric loop domain: the Cartesian product of [`Dim`]s.
/// Bounds may reference parameters but not other dimensions (all kernels
/// in the paper are rectangular after tiling is expressed with ceil-div
/// bounds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BoxDomain {
    pub dims: Vec<Dim>,
}

impl BoxDomain {
    pub fn new(dims: Vec<Dim>) -> BoxDomain {
        BoxDomain { dims }
    }

    pub fn dim<S: Into<Sym>>(&self, name: S) -> Option<&Dim> {
        let sym = name.into();
        self.dims.iter().find(|d| d.name == sym)
    }

    /// Project onto the named dimensions (drop the rest). Valid because
    /// dims are independent.
    pub fn project_onto(&self, names: &[Sym]) -> BoxDomain {
        BoxDomain {
            dims: self.dims.iter().filter(|d| names.contains(&d.name)).cloned().collect(),
        }
    }

    /// Symbolic point count: `Π trip(dim)` guarded by non-emptiness of
    /// every dim. (If any dim is empty the true count is 0, which is what
    /// `PwQPoly::eval` returns when a guard fails.)
    pub fn count(&self) -> PwQPoly {
        let mut q = QPoly::one();
        let mut guards = Vec::new();
        for d in &self.dims {
            q = q.mul(&d.trip_count());
            // Constant-true guards are dropped; constant-false make the
            // domain statically empty.
            let g = d.nonempty_guard();
            if g.0.is_constant() {
                if g.0.c < 0 {
                    return PwQPoly::zero();
                }
            } else {
                guards.push(g);
            }
        }
        PwQPoly { pieces: vec![(guards, q)] }
    }

    /// Concrete point count (cross-check for `count`).
    pub fn count_at(&self, env: &Env) -> Result<i64, String> {
        let mut n = 1i64;
        for d in &self.dims {
            n *= d.trip_count_at(env)?;
            if n == 0 {
                return Ok(0);
            }
        }
        Ok(n)
    }
}

/// A conjunction of affine constraints `e >= 0` over named dims and
/// parameters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Conjunct {
    pub constraints: Vec<LinExpr>,
}

/// General integer set: disjunction of conjunctions over `dims`,
/// parametric in whatever parameters the constraints mention. This is the
/// fallback ("simpler counting technique") path: exact enumeration at a
/// concrete binding.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Set {
    pub dims: Vec<Sym>,
    pub disjuncts: Vec<Conjunct>,
}

impl Set {
    pub fn new(dims: Vec<Sym>) -> Set {
        Set { dims, disjuncts: vec![Conjunct::default()] }
    }

    /// Add `e >= 0` to every disjunct (intersection with a half-space).
    pub fn constrain(mut self, e: LinExpr) -> Set {
        for d in &mut self.disjuncts {
            d.constraints.push(e.clone());
        }
        self
    }

    /// Union with another set over the same dims.
    pub fn union(mut self, other: Set) -> Set {
        assert_eq!(self.dims, other.dims, "union requires identical dim tuples");
        self.disjuncts.extend(other.disjuncts);
        self
    }

    /// Derive [lo, hi] bounds for dim `i` in a conjunct, given fixed
    /// earlier dims and parameters. Constraints mentioning later dims are
    /// skipped (they are checked when those dims are fixed).
    fn bounds_for(
        &self,
        conj: &Conjunct,
        i: usize,
        fixed: &Env,
    ) -> Result<Option<(i64, i64)>, String> {
        let name = self.dims[i];
        let later = &self.dims[i + 1..];
        let (mut lo, mut hi) = (i64::MIN / 4, i64::MAX / 4);
        let mut bounded = false;
        for c in &conj.constraints {
            if later.iter().any(|d| c.coeff(*d) != 0) {
                continue;
            }
            let k = c.coeff(name);
            if k == 0 {
                continue;
            }
            // Evaluate the rest of the constraint with fixed values.
            let mut rest = c.clone();
            rest.terms.remove(&name);
            let r = rest.eval(fixed)?;
            if k > 0 {
                // k*v + r >= 0  ->  v >= ceil(-r/k)
                lo = lo.max(div_ceil(-r, k));
            } else {
                // k*v + r >= 0  ->  v <= floor(r/(-k))
                hi = hi.min(r.div_euclid(-k));
            }
            bounded = true;
        }
        if !bounded || lo <= i64::MIN / 8 || hi >= i64::MAX / 8 {
            return Err(format!("dim '{name}' is unbounded in enumeration fallback"));
        }
        if lo > hi {
            return Ok(None);
        }
        Ok(Some((lo, hi)))
    }

    fn conj_holds(conj: &Conjunct, env: &Env) -> Result<bool, String> {
        for c in &conj.constraints {
            if c.eval(env)? < 0 {
                return Ok(false);
            }
        }
        Ok(true)
    }

    /// Enumerate the points of one conjunct.
    fn enumerate_conj(
        &self,
        conj: &Conjunct,
        i: usize,
        fixed: &mut Env,
        out: &mut Vec<Vec<i64>>,
    ) -> Result<(), String> {
        if i == self.dims.len() {
            if Self::conj_holds(conj, fixed)? {
                out.push(
                    self.dims
                        .iter()
                        .map(|d| fixed.get(*d).expect("enumerated dim is bound"))
                        .collect(),
                );
            }
            return Ok(());
        }
        let Some((lo, hi)) = self.bounds_for(conj, i, fixed)? else {
            return Ok(());
        };
        for v in lo..=hi {
            fixed.bind(self.dims[i], v);
            self.enumerate_conj(conj, i + 1, fixed, out)?;
        }
        fixed.unbind(self.dims[i]);
        Ok(())
    }

    /// Count points at a concrete parameter binding. Handles overlapping
    /// disjuncts by deduplicating enumerated points.
    pub fn count_at(&self, params: &Env) -> Result<i64, String> {
        let mut all: Vec<Vec<i64>> = Vec::new();
        for conj in &self.disjuncts {
            let mut fixed = params.clone();
            self.enumerate_conj(conj, 0, &mut fixed, &mut all)?;
        }
        all.sort();
        all.dedup();
        Ok(all.len() as i64)
    }
}

/// Convert a [`BoxDomain`] into a general [`Set`] (for cross-checking the
/// symbolic path against the enumeration path). Strided dims are encoded
/// by an auxiliary congruence dim — instead we simply expand them: a
/// strided dim `v in {0, s, 2s, ...} ∩ [0, hi)` is represented by dim `t`
/// with `v = s*t`, so the Set uses the *trip space*.
pub fn box_to_trip_set(b: &BoxDomain) -> Set {
    let mut s = Set::new(
        b.dims.iter().map(|d| Sym::intern(&format!("t_{}", d.name))).collect(),
    );
    for d in &b.dims {
        let t = Sym::intern(&format!("t_{}", d.name));
        // t >= 0
        s = s.constrain(LinExpr::scaled_var(t.as_str(), 1));
        // lo + step*t < hi  ->  hi_num - den*(lo + step*t) - 1 >= 0
        // (for den = 1 this is hi - lo - step*t - 1 >= 0; exact for den>=1
        //  because t < ceil(num/den) <=> den*t < num  when lo = 0 and
        //  step = 1; for general lo/step we require den == 1.)
        if d.hi.den == 1 {
            let mut e = d.hi.num.sub(&d.lo).add(&LinExpr::constant(-1));
            e.add_term(t, -d.step);
            s = s.constrain(e);
        } else {
            assert!(d.lo.is_constant() && d.lo.c == 0 && d.step == 1);
            let mut e = d.hi.num.clone();
            e.add_term(t, -d.hi.den);
            // den*t < num  <=>  num - den*t - 1 >= 0
            s = s.constrain(e.add(&LinExpr::constant(-1)));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qpoly::env;

    #[test]
    fn simple_box_count() {
        // {[i,j] : 0<=i<n, 0<=j<m} -> n*m
        let b = BoxDomain::new(vec![
            Dim::simple("i", LinExpr::var("n")),
            Dim::simple("j", LinExpr::var("m")),
        ]);
        let c = b.count();
        assert_eq!(c.eval(&env(&[("n", 12), ("m", 7)])).unwrap(), 84.0);
        // empty when n = 0
        assert_eq!(c.eval(&env(&[("n", 0), ("m", 7)])).unwrap(), 0.0);
    }

    #[test]
    fn strided_dim_count() {
        // every third element of [0, n)
        let b = BoxDomain::new(vec![Dim::strided("i", LinExpr::var("n"), 3)]);
        for n in [1i64, 2, 3, 7, 9, 100] {
            let want = div_ceil(n, 3) as f64;
            assert_eq!(b.count().eval(&env(&[("n", n)])).unwrap(), want, "n={n}");
        }
    }

    #[test]
    fn tiled_dim_count() {
        // tile loop 0 <= t < ceil(n/16)
        let b = BoxDomain::new(vec![Dim::tiles("t", LinExpr::var("n"), 16)]);
        assert_eq!(b.count().eval(&env(&[("n", 16)])).unwrap(), 1.0);
        assert_eq!(b.count().eval(&env(&[("n", 17)])).unwrap(), 2.0);
        assert_eq!(b.count().eval(&env(&[("n", 256)])).unwrap(), 16.0);
    }

    #[test]
    fn projection_drops_dims() {
        let b = BoxDomain::new(vec![
            Dim::simple("i", LinExpr::var("n")),
            Dim::simple("j", LinExpr::var("m")),
            Dim::simple("k", LinExpr::var("l")),
        ]);
        let p = b.project_onto(&["i".into(), "k".into()]);
        assert_eq!(p.dims.len(), 2);
        assert_eq!(p.count().eval(&env(&[("n", 3), ("l", 5)])).unwrap(), 15.0);
    }

    #[test]
    fn count_at_matches_symbolic() {
        let b = BoxDomain::new(vec![
            Dim::strided("i", LinExpr::var("n"), 2),
            Dim::tiles("t", LinExpr::var("m"), 12),
        ]);
        for (n, m) in [(10i64, 12i64), (11, 13), (1, 1), (64, 144)] {
            let e = env(&[("n", n), ("m", m)]);
            assert_eq!(b.count().eval(&e).unwrap(), b.count_at(&e).unwrap() as f64);
        }
    }

    #[test]
    fn enumeration_set_triangle() {
        // {[i,j] : 0<=i<n, 0<=j<=i} -> n(n+1)/2
        let mut s = Set::new(vec!["i".into(), "j".into()]);
        s = s.constrain(LinExpr::var("i"));
        s = s.constrain(LinExpr::var("n").sub(&LinExpr::var("i")).sub(&LinExpr::constant(1)));
        s = s.constrain(LinExpr::var("j"));
        s = s.constrain(LinExpr::var("i").sub(&LinExpr::var("j")));
        for n in [1i64, 2, 5, 10] {
            assert_eq!(s.count_at(&env(&[("n", n)])).unwrap(), n * (n + 1) / 2);
        }
    }

    #[test]
    fn enumeration_detects_unbounded() {
        let s = Set::new(vec!["i".into()]).constrain(LinExpr::var("i")); // i >= 0 only
        assert!(s.count_at(&env(&[])).is_err());
    }

    #[test]
    fn union_dedups_overlap() {
        // [0, 10) ∪ [5, 15) = [0, 15) -> 15 points
        let half = |lo: i64, hi: i64| {
            Set::new(vec!["i".into()])
                .constrain(LinExpr::var("i").sub(&LinExpr::constant(lo)))
                .constrain(LinExpr::constant(hi - 1).sub(&LinExpr::var("i")))
        };
        let u = half(0, 10).union(half(5, 15));
        assert_eq!(u.count_at(&env(&[])).unwrap(), 15);
    }

    #[test]
    fn box_vs_enumeration_crosscheck() {
        let b = BoxDomain::new(vec![
            Dim::simple("i", LinExpr::var("n")),
            Dim::strided("j", LinExpr::var("m"), 3),
        ]);
        let s = box_to_trip_set(&b);
        for (n, m) in [(4i64, 9i64), (5, 10), (1, 1), (8, 2)] {
            let e = env(&[("n", n), ("m", m)]);
            assert_eq!(
                b.count().eval(&e).unwrap(),
                s.count_at(&e).unwrap() as f64,
                "n={n} m={m}"
            );
        }
    }

    #[test]
    fn statically_empty_box() {
        let b = BoxDomain::new(vec![Dim::simple("i", LinExpr::constant(0))]);
        assert!(b.count().is_zero());
    }
}
