//! Piecewise quasi-polynomials — the symbolic representation of operation
//! counts (paper §3.2).
//!
//! Counting the integer points of a parametric loop domain yields a
//! *piecewise quasi-polynomial* in the size parameters (Verdoolaege et
//! al.): a polynomial whose "variables" are either parameters (`n`, `m`,
//! …) or integer floor divisions of affine parameter expressions
//! (`floor((n+15)/16)` — these arise from tiling and strided loops).
//!
//! This module implements the closed arithmetic on those objects
//! (addition, multiplication, scaling) plus evaluation at a concrete
//! parameter binding, which is all the model needs: property expressions
//! `p_i(n)` are built symbolically once and cheaply re-evaluated for
//! changed `n` (the paper's "fully parametric" claim).
//!
//! Identifiers are interned [`Sym`]s and bindings are dense [`Env`]
//! slot frames, so evaluation never touches string keys. For the
//! hottest re-evaluation paths, [`tape`] compiles expressions into flat
//! postfix tapes over slot indices ([`tape::LinTape`] /
//! [`tape::PwTape`]).

use std::collections::BTreeMap;
use std::fmt;

pub mod tape;

use crate::util::json::Json;
pub use crate::util::intern::{Env, Sym};

/// Checked accumulation of one affine term: `acc + k*v`, where `v` is the
/// value bound to `sym`. Shared by the tree-walking evaluators *and* the
/// compiled tapes so both paths surface the identical diagnostic on
/// overflow (the batch/scalar equivalence suite pins this).
#[inline]
pub(crate) fn checked_term(acc: i64, k: i64, v: i64, sym: Sym) -> Result<i64, String> {
    k.checked_mul(v)
        .and_then(|t| acc.checked_add(t))
        .ok_or_else(|| format!("i64 overflow evaluating affine term {k}*{sym} with {sym} = {v}"))
}

/// Checked `floor(n / den)`. Covers `den == 0` and `i64::MIN / -1`, which
/// would otherwise panic in debug builds or wrap in release on hostile
/// bindings.
#[inline]
pub(crate) fn checked_floordiv(n: i64, den: i64) -> Result<i64, String> {
    n.checked_div_euclid(den)
        .ok_or_else(|| format!("invalid floor division floor(({n})/{den})"))
}

/// Affine integer expression: `Σ c_v · v + c0` over named parameters.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct LinExpr {
    /// parameter symbol -> coefficient (zero coefficients are not stored)
    pub terms: BTreeMap<Sym, i64>,
    /// constant term
    pub c: i64,
}

impl LinExpr {
    pub fn constant(c: i64) -> LinExpr {
        LinExpr { terms: BTreeMap::new(), c }
    }

    pub fn var(name: &str) -> LinExpr {
        let mut terms = BTreeMap::new();
        terms.insert(Sym::intern(name), 1);
        LinExpr { terms, c: 0 }
    }

    pub fn scaled_var(name: &str, k: i64) -> LinExpr {
        let mut e = LinExpr::constant(0);
        e.add_term(name, k);
        e
    }

    pub fn add_term<S: Into<Sym>>(&mut self, name: S, k: i64) {
        if k == 0 {
            return;
        }
        let sym = name.into();
        let entry = self.terms.entry(sym).or_insert(0);
        *entry += k;
        if *entry == 0 {
            self.terms.remove(&sym);
        }
    }

    pub fn add(&self, other: &LinExpr) -> LinExpr {
        let mut out = self.clone();
        out.c += other.c;
        for (v, k) in &other.terms {
            out.add_term(*v, *k);
        }
        out
    }

    pub fn sub(&self, other: &LinExpr) -> LinExpr {
        self.add(&other.neg())
    }

    pub fn neg(&self) -> LinExpr {
        LinExpr {
            terms: self.terms.iter().map(|(v, k)| (*v, -k)).collect(),
            c: -self.c,
        }
    }

    pub fn scale(&self, k: i64) -> LinExpr {
        if k == 0 {
            return LinExpr::constant(0);
        }
        LinExpr {
            terms: self.terms.iter().map(|(v, c)| (*v, c * k)).collect(),
            c: self.c * k,
        }
    }

    pub fn is_constant(&self) -> bool {
        self.terms.is_empty()
    }

    /// Coefficient of a parameter (0 if absent).
    pub fn coeff<S: Into<Sym>>(&self, name: S) -> i64 {
        self.terms.get(&name.into()).copied().unwrap_or(0)
    }

    /// Evaluate with a parameter binding; errors on unbound parameters
    /// and on `i64` overflow. Client-supplied bindings reach this path
    /// through inline-spec requests, so wraparound must surface as an
    /// `Err`, never as a silently wrong count.
    pub fn eval(&self, env: &Env) -> Result<i64, String> {
        let mut acc = self.c;
        for (v, k) in &self.terms {
            let val = env
                .get(*v)
                .ok_or_else(|| format!("unbound parameter '{v}'"))?;
            acc = checked_term(acc, *k, val, *v)?;
        }
        Ok(acc)
    }

    /// Substitute a parameter with an affine expression.
    pub fn substitute<S: Into<Sym>>(&self, name: S, with: &LinExpr) -> LinExpr {
        let sym = name.into();
        let k = self.coeff(sym);
        if k == 0 {
            return self.clone();
        }
        let mut out = self.clone();
        out.terms.remove(&sym);
        out.add(&with.scale(k))
    }
}

impl fmt::Display for LinExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (v, k) in &self.terms {
            if *k == 1 && !first {
                write!(f, " + {v}")?;
            } else if *k == 1 {
                write!(f, "{v}")?;
            } else if *k == -1 {
                write!(f, "{}-{v}", if first { "" } else { " " })?;
            } else if *k < 0 {
                write!(f, "{}{k}*{v}", if first { "" } else { " " })?;
            } else if first {
                write!(f, "{k}*{v}")?;
            } else {
                write!(f, " + {k}*{v}")?;
            }
            first = false;
        }
        if first {
            write!(f, "{}", self.c)?;
        } else if self.c > 0 {
            write!(f, " + {}", self.c)?;
        } else if self.c < 0 {
            write!(f, " - {}", -self.c)?;
        }
        Ok(())
    }
}

/// A multiplicative atom of a quasi-polynomial term.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Atom {
    /// a bare parameter
    Param(Sym),
    /// `floor(num / den)`, `den > 0`
    FloorDiv(LinExpr, i64),
}

impl Atom {
    pub fn eval(&self, env: &Env) -> Result<i64, String> {
        match self {
            Atom::Param(p) => {
                env.get(*p).ok_or_else(|| format!("unbound parameter '{p}'"))
            }
            Atom::FloorDiv(num, den) => {
                let n = num.eval(env)?;
                checked_floordiv(n, *den)
            }
        }
    }
}

impl fmt::Display for Atom {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Atom::Param(p) => write!(f, "{p}"),
            Atom::FloorDiv(num, den) => write!(f, "floor(({num})/{den})"),
        }
    }
}

/// Product of atoms with exponents; the "1" monomial is the empty map.
pub type Monomial = BTreeMap<Atom, u32>;

/// Quasi-polynomial: map monomial -> coefficient.
///
/// Coefficients are `f64` but remain exact for all integer counts below
/// 2^53, which comfortably covers every kernel in the paper.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct QPoly {
    pub terms: BTreeMap<Monomial, f64>,
}

impl QPoly {
    pub fn zero() -> QPoly {
        QPoly::default()
    }

    pub fn constant(c: f64) -> QPoly {
        let mut q = QPoly::zero();
        if c != 0.0 {
            q.terms.insert(Monomial::new(), c);
        }
        q
    }

    pub fn one() -> QPoly {
        QPoly::constant(1.0)
    }

    pub fn param(name: &str) -> QPoly {
        QPoly::from_atom(Atom::Param(Sym::intern(name)))
    }

    pub fn from_atom(a: Atom) -> QPoly {
        // constant-fold floor of a constant
        if let Atom::FloorDiv(num, den) = &a {
            if num.is_constant() {
                return QPoly::constant(num.c.div_euclid(*den) as f64);
            }
        }
        let mut m = Monomial::new();
        m.insert(a, 1);
        let mut q = QPoly::zero();
        q.terms.insert(m, 1.0);
        q
    }

    /// Lift an affine expression into a quasi-polynomial.
    pub fn from_lin(e: &LinExpr) -> QPoly {
        let mut q = QPoly::constant(e.c as f64);
        for (v, k) in &e.terms {
            q = q.add(&QPoly::from_atom(Atom::Param(*v)).scale(*k as f64));
        }
        q
    }

    pub fn is_zero(&self) -> bool {
        self.terms.is_empty()
    }

    /// `self` as a constant if it has no parametric terms.
    pub fn as_constant(&self) -> Option<f64> {
        match self.terms.len() {
            0 => Some(0.0),
            1 => {
                let (m, c) = self.terms.iter().next().unwrap();
                if m.is_empty() {
                    Some(*c)
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    fn insert_term(&mut self, m: Monomial, c: f64) {
        if c == 0.0 {
            return;
        }
        let entry = self.terms.entry(m.clone()).or_insert(0.0);
        *entry += c;
        if *entry == 0.0 {
            self.terms.remove(&m);
        }
    }

    pub fn add(&self, other: &QPoly) -> QPoly {
        let mut out = self.clone();
        for (m, c) in &other.terms {
            out.insert_term(m.clone(), *c);
        }
        out
    }

    pub fn sub(&self, other: &QPoly) -> QPoly {
        self.add(&other.scale(-1.0))
    }

    pub fn scale(&self, k: f64) -> QPoly {
        if k == 0.0 {
            return QPoly::zero();
        }
        QPoly { terms: self.terms.iter().map(|(m, c)| (m.clone(), c * k)).collect() }
    }

    pub fn mul(&self, other: &QPoly) -> QPoly {
        let mut out = QPoly::zero();
        for (ma, ca) in &self.terms {
            for (mb, cb) in &other.terms {
                let mut m = ma.clone();
                for (atom, e) in mb {
                    *m.entry(atom.clone()).or_insert(0) += e;
                }
                out.insert_term(m, ca * cb);
            }
        }
        out
    }

    /// Evaluate at a concrete parameter binding.
    pub fn eval(&self, env: &Env) -> Result<f64, String> {
        let mut acc = 0.0;
        for (m, c) in &self.terms {
            let mut term = *c;
            for (atom, e) in m {
                let v = atom.eval(env)? as f64;
                term *= v.powi(*e as i32);
            }
            acc += term;
        }
        Ok(acc)
    }

    /// Total degree (parameters and floor-atoms each count as degree 1).
    pub fn degree(&self) -> u32 {
        self.terms.keys().map(|m| m.values().sum::<u32>()).max().unwrap_or(0)
    }
}

impl fmt::Display for QPoly {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.terms.is_empty() {
            return write!(f, "0");
        }
        let mut first = true;
        for (m, c) in &self.terms {
            if !first {
                write!(f, " + ")?;
            }
            first = false;
            if m.is_empty() {
                write!(f, "{c}")?;
                continue;
            }
            if *c != 1.0 {
                write!(f, "{c}*")?;
            }
            let mut first_atom = true;
            for (atom, e) in m {
                if !first_atom {
                    write!(f, "*")?;
                }
                first_atom = false;
                if *e == 1 {
                    write!(f, "{atom}")?;
                } else {
                    write!(f, "{atom}^{e}")?;
                }
            }
        }
        Ok(())
    }
}

/// An affine constraint `expr >= 0`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Guard(pub LinExpr);

impl Guard {
    pub fn holds(&self, env: &Env) -> Result<bool, String> {
        Ok(self.0.eval(env)? >= 0)
    }
}

/// Piecewise quasi-polynomial: guarded pieces evaluated first-match. The
/// pieces produced by our counting are disjoint; `eval` returns 0 if no
/// guard holds (matching isl's semantics of counting an empty set).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PwQPoly {
    pub pieces: Vec<(Vec<Guard>, QPoly)>,
}

impl PwQPoly {
    pub fn from_qpoly(q: QPoly) -> PwQPoly {
        PwQPoly { pieces: vec![(Vec::new(), q)] }
    }

    pub fn zero() -> PwQPoly {
        PwQPoly::from_qpoly(QPoly::zero())
    }

    pub fn constant(c: f64) -> PwQPoly {
        PwQPoly::from_qpoly(QPoly::constant(c))
    }

    pub fn eval(&self, env: &Env) -> Result<f64, String> {
        for (guards, q) in &self.pieces {
            let mut ok = true;
            for g in guards {
                if !g.holds(env)? {
                    ok = false;
                    break;
                }
            }
            if ok {
                return q.eval(env);
            }
        }
        Ok(0.0)
    }

    /// Binary combination: cross product of pieces, merging guards.
    fn combine(&self, other: &PwQPoly, f: impl Fn(&QPoly, &QPoly) -> QPoly) -> PwQPoly {
        let mut pieces = Vec::new();
        for (ga, qa) in &self.pieces {
            for (gb, qb) in &other.pieces {
                let mut g = ga.clone();
                g.extend(gb.iter().cloned());
                pieces.push((g, f(qa, qb)));
            }
        }
        PwQPoly { pieces }
    }

    pub fn add(&self, other: &PwQPoly) -> PwQPoly {
        // Fast path: both single-piece and guard-free.
        if self.pieces.len() == 1
            && other.pieces.len() == 1
            && self.pieces[0].0.is_empty()
            && other.pieces[0].0.is_empty()
        {
            return PwQPoly::from_qpoly(self.pieces[0].1.add(&other.pieces[0].1));
        }
        self.combine(other, |a, b| a.add(b))
    }

    pub fn mul(&self, other: &PwQPoly) -> PwQPoly {
        self.combine(other, |a, b| a.mul(b))
    }

    pub fn scale(&self, k: f64) -> PwQPoly {
        PwQPoly {
            pieces: self.pieces.iter().map(|(g, q)| (g.clone(), q.scale(k))).collect(),
        }
    }

    /// Whether every piece is the zero polynomial.
    pub fn is_zero(&self) -> bool {
        self.pieces.iter().all(|(_, q)| q.is_zero())
    }
}

impl fmt::Display for PwQPoly {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.pieces.len() == 1 && self.pieces[0].0.is_empty() {
            return write!(f, "{}", self.pieces[0].1);
        }
        for (i, (guards, q)) in self.pieces.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            if !guards.is_empty() {
                write!(f, "[")?;
                for (j, g) in guards.iter().enumerate() {
                    if j > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{} >= 0", g.0)?;
                }
                write!(f, "] -> ")?;
            }
            write!(f, "{q}")?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// JSON round-trip — used by the persistent extraction cache (service) to
// serialize `KernelProps` bodies. `i64` values are encoded as decimal
// strings when they do not fit exactly in an f64 JSON number (|x| >= 2^53);
// f64 coefficients rely on Rust's shortest-round-trip `Display`.

fn i64_to_json(x: i64) -> Json {
    if x.unsigned_abs() < (1u64 << 53) {
        Json::Num(x as f64)
    } else {
        Json::Str(x.to_string())
    }
}

fn i64_from_json(j: &Json) -> Result<i64, String> {
    if let Some(x) = j.as_i64() {
        return Ok(x);
    }
    match j {
        Json::Str(s) => s.parse::<i64>().map_err(|e| format!("bad i64 '{s}': {e}")),
        other => Err(format!("expected i64, got {}", other.compact())),
    }
}

impl LinExpr {
    pub fn to_json(&self) -> Json {
        let terms = self
            .terms
            .iter()
            .map(|(v, k)| Json::Arr(vec![Json::Str(v.to_string()), i64_to_json(*k)]))
            .collect();
        Json::obj(vec![("c", i64_to_json(self.c)), ("t", Json::Arr(terms))])
    }

    pub fn from_json(j: &Json) -> Result<LinExpr, String> {
        let c = i64_from_json(j.get("c").ok_or("LinExpr: missing 'c'")?)?;
        let Some(Json::Arr(ts)) = j.get("t") else {
            return Err("LinExpr: missing 't'".into());
        };
        let mut terms = BTreeMap::new();
        for t in ts {
            let Json::Arr(pair) = t else {
                return Err("LinExpr: term is not a pair".into());
            };
            let [name, k] = pair.as_slice() else {
                return Err("LinExpr: term is not a pair".into());
            };
            let Json::Str(name) = name else {
                return Err("LinExpr: term name is not a string".into());
            };
            terms.insert(Sym::intern(name), i64_from_json(k)?);
        }
        Ok(LinExpr { terms, c })
    }
}

impl Atom {
    pub fn to_json(&self) -> Json {
        match self {
            Atom::Param(p) => Json::Str(p.to_string()),
            Atom::FloorDiv(num, den) => {
                Json::obj(vec![("num", num.to_json()), ("den", i64_to_json(*den))])
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Atom, String> {
        match j {
            Json::Str(name) => Ok(Atom::Param(Sym::intern(name))),
            Json::Obj(_) => Ok(Atom::FloorDiv(
                LinExpr::from_json(j.get("num").ok_or("Atom: missing 'num'")?)?,
                i64_from_json(j.get("den").ok_or("Atom: missing 'den'")?)?,
            )),
            other => Err(format!("Atom: unexpected {}", other.compact())),
        }
    }
}

impl QPoly {
    pub fn to_json(&self) -> Json {
        let terms = self
            .terms
            .iter()
            .map(|(m, c)| {
                let factors = m
                    .iter()
                    .map(|(a, e)| Json::Arr(vec![a.to_json(), Json::Num(f64::from(*e))]))
                    .collect();
                Json::obj(vec![("c", Json::Num(*c)), ("m", Json::Arr(factors))])
            })
            .collect();
        Json::Arr(terms)
    }

    pub fn from_json(j: &Json) -> Result<QPoly, String> {
        let Json::Arr(ts) = j else {
            return Err("QPoly: expected array".into());
        };
        let mut q = QPoly::zero();
        for t in ts {
            let c = t.get_f64("c").ok_or("QPoly: term missing 'c'")?;
            let Some(Json::Arr(ms)) = t.get("m") else {
                return Err("QPoly: term missing 'm'".into());
            };
            let mut m = Monomial::new();
            for f in ms {
                let Json::Arr(pair) = f else {
                    return Err("QPoly: factor is not a pair".into());
                };
                let [a, e] = pair.as_slice() else {
                    return Err("QPoly: factor is not a pair".into());
                };
                let e = e
                    .as_i64()
                    .filter(|&e| e > 0 && e <= i64::from(u32::MAX))
                    .ok_or("QPoly: bad exponent")?;
                *m.entry(Atom::from_json(a)?).or_insert(0) += e as u32;
            }
            q.insert_term(m, c);
        }
        Ok(q)
    }
}

impl PwQPoly {
    pub fn to_json(&self) -> Json {
        let pieces = self
            .pieces
            .iter()
            .map(|(guards, q)| {
                let gs = guards.iter().map(|g| g.0.to_json()).collect();
                Json::obj(vec![("g", Json::Arr(gs)), ("q", q.to_json())])
            })
            .collect();
        Json::Arr(pieces)
    }

    pub fn from_json(j: &Json) -> Result<PwQPoly, String> {
        let Json::Arr(ps) = j else {
            return Err("PwQPoly: expected array".into());
        };
        let mut pieces = Vec::with_capacity(ps.len());
        for p in ps {
            let Some(Json::Arr(gs)) = p.get("g") else {
                return Err("PwQPoly: piece missing 'g'".into());
            };
            let mut guards = Vec::with_capacity(gs.len());
            for g in gs {
                guards.push(Guard(LinExpr::from_json(g)?));
            }
            let q = QPoly::from_json(p.get("q").ok_or("PwQPoly: piece missing 'q'")?)?;
            pieces.push((guards, q));
        }
        Ok(PwQPoly { pieces })
    }
}

/// Convenience: parameter environment builder.
pub fn env(pairs: &[(&str, i64)]) -> Env {
    Env::from_pairs(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linexpr_arith_and_eval() {
        let e = LinExpr::var("n").scale(2).add(&LinExpr::constant(3)); // 2n+3
        assert_eq!(e.eval(&env(&[("n", 5)])).unwrap(), 13);
        let f = e.sub(&LinExpr::var("n")); // n+3
        assert_eq!(f.eval(&env(&[("n", 5)])).unwrap(), 8);
        assert!(e.eval(&env(&[])).is_err());
    }

    #[test]
    fn linexpr_cancellation() {
        let e = LinExpr::var("n").sub(&LinExpr::var("n"));
        assert!(e.is_constant());
        assert_eq!(e.c, 0);
    }

    #[test]
    fn linexpr_substitute() {
        // e = 2i + 3, i := 16*t + l  ->  32t + 2l + 3
        let e = LinExpr::scaled_var("i", 2).add(&LinExpr::constant(3));
        let with = LinExpr::scaled_var("t", 16).add(&LinExpr::var("l"));
        let s = e.substitute("i", &with);
        assert_eq!(s.coeff("t"), 32);
        assert_eq!(s.coeff("l"), 2);
        assert_eq!(s.c, 3);
        assert_eq!(s.coeff("i"), 0);
    }

    #[test]
    fn qpoly_mul_expands() {
        // (n + 1) * (n + 2) = n^2 + 3n + 2
        let n1 = QPoly::param("n").add(&QPoly::one());
        let n2 = QPoly::param("n").add(&QPoly::constant(2.0));
        let p = n1.mul(&n2);
        let e = env(&[("n", 7)]);
        assert_eq!(p.eval(&e).unwrap(), (7.0 + 1.0) * (7.0 + 2.0));
        assert_eq!(p.degree(), 2);
    }

    #[test]
    fn floordiv_atom_eval() {
        // floor((n+15)/16) — tile count
        let fd = Atom::FloorDiv(LinExpr::var("n").add(&LinExpr::constant(15)), 16);
        assert_eq!(fd.eval(&env(&[("n", 1)])).unwrap(), 1);
        assert_eq!(fd.eval(&env(&[("n", 16)])).unwrap(), 1);
        assert_eq!(fd.eval(&env(&[("n", 17)])).unwrap(), 2);
    }

    #[test]
    fn floordiv_constant_folds() {
        let q = QPoly::from_atom(Atom::FloorDiv(LinExpr::constant(37), 16));
        assert_eq!(q.as_constant(), Some(2.0));
    }

    #[test]
    fn qpoly_add_cancels() {
        let p = QPoly::param("n").sub(&QPoly::param("n"));
        assert!(p.is_zero());
    }

    #[test]
    fn display_readable() {
        let n = QPoly::param("n");
        let p = n.mul(&n).scale(2.0).add(&QPoly::constant(1.0));
        let s = format!("{p}");
        assert!(s.contains("n^2"), "{s}");
    }

    #[test]
    fn piecewise_eval_guard() {
        // piece 1: n - 4 >= 0 -> n^2 ; else 0
        let pw = PwQPoly {
            pieces: vec![(
                vec![Guard(LinExpr::var("n").sub(&LinExpr::constant(4)))],
                QPoly::param("n").mul(&QPoly::param("n")),
            )],
        };
        assert_eq!(pw.eval(&env(&[("n", 8)])).unwrap(), 64.0);
        assert_eq!(pw.eval(&env(&[("n", 2)])).unwrap(), 0.0);
    }

    #[test]
    fn piecewise_combine_merges_guards() {
        let a = PwQPoly {
            pieces: vec![(vec![Guard(LinExpr::var("n"))], QPoly::param("n"))],
        };
        let b = PwQPoly::constant(3.0);
        let s = a.mul(&b);
        assert_eq!(s.eval(&env(&[("n", 5)])).unwrap(), 15.0);
        assert_eq!(s.pieces[0].0.len(), 1);
    }

    #[test]
    fn eval_overflow_is_an_error_not_a_wrap() {
        let e = LinExpr::scaled_var("n", 3);
        let err = e.eval(&env(&[("n", i64::MAX / 2)])).unwrap_err();
        assert!(err.contains("overflow"), "{err}");
        // accumulator overflow: MAX + MAX
        let mut e = LinExpr::constant(i64::MAX);
        e.add_term("n", 1);
        assert!(e.eval(&env(&[("n", i64::MAX)])).is_err());
        // floor division by zero is an error, not a panic
        let fd = Atom::FloorDiv(LinExpr::var("n"), 0);
        assert!(fd.eval(&env(&[("n", 1)])).is_err());
        // in-range values still evaluate
        assert_eq!(LinExpr::scaled_var("n", 3).eval(&env(&[("n", 4)])).unwrap(), 12);
    }

    #[test]
    fn json_round_trip_preserves_structure() {
        let pw = PwQPoly {
            pieces: vec![
                (
                    vec![Guard(LinExpr::var("n").sub(&LinExpr::constant(4)))],
                    QPoly::param("n").mul(&QPoly::param("m")).add(
                        &QPoly::from_atom(Atom::FloorDiv(
                            LinExpr::var("n").add(&LinExpr::constant(15)),
                            16,
                        ))
                        .scale(2.5),
                    ),
                ),
                (Vec::new(), QPoly::constant(7.0)),
            ],
        };
        let wire = pw.to_json().compact();
        let back = PwQPoly::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, pw);
        // i64s beyond 2^53 travel as strings, losslessly
        let mut lin = LinExpr::constant(i64::MIN + 1);
        lin.add_term("n", i64::MAX);
        let wire = lin.to_json().compact();
        let back = LinExpr::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, lin);
    }

    #[test]
    fn eval_matches_structure_after_arith() {
        // p = (n*m + 2n + 1) * floor(n/2)
        let nm = QPoly::param("n").mul(&QPoly::param("m"));
        let p = nm
            .add(&QPoly::param("n").scale(2.0))
            .add(&QPoly::one())
            .mul(&QPoly::from_atom(Atom::FloorDiv(LinExpr::var("n"), 2)));
        let e = env(&[("n", 9), ("m", 4)]);
        let want = ((9 * 4 + 2 * 9 + 1) * (9 / 2)) as f64;
        assert_eq!(p.eval(&e).unwrap(), want);
    }
}
