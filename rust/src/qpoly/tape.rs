//! Compiled evaluation tapes for affine expressions and (piecewise)
//! quasi-polynomials.
//!
//! Symbolic property counts are built once per kernel and then
//! re-evaluated for many parameter bindings (size sweeps, the
//! measurement campaign, autotuning loops, prediction serving). The
//! tree-walking evaluators in [`crate::qpoly`] are exact but chase
//! `BTreeMap` nodes on every call; this module flattens an expression
//! into contiguous arrays of slot-indexed operations that evaluate with
//! a single linear pass over the tape and O(1) [`Env`] slot loads — no
//! string comparison, no map probing, no per-eval allocation (atom
//! scratch lives in a thread-local buffer).
//!
//! Compilation preserves the exact term/atom/guard ordering of the
//! source object, so tape evaluation is bit-identical to the
//! tree-walking path (verified by property tests in
//! `rust/tests/properties.rs`).

use super::{Atom, LinExpr, PwQPoly, QPoly};
use crate::util::intern::{Env, Sym};
use std::cell::RefCell;

/// Compiled affine expression: `c + Σ coeff · frame[slot]`.
#[derive(Clone, Debug, Default)]
pub struct LinTape {
    pub c: i64,
    /// `(symbol slot id, coefficient)` pairs in symbol order
    pub terms: Box<[(u32, i64)]>,
}

impl LinTape {
    pub fn compile(e: &LinExpr) -> LinTape {
        LinTape {
            c: e.c,
            terms: e.terms.iter().map(|(s, k)| (s.id(), *k)).collect(),
        }
    }

    /// Evaluate against a slot frame; errors on unbound slots.
    #[inline]
    pub fn eval(&self, env: &Env) -> Result<i64, String> {
        let mut acc = self.c;
        for &(slot, k) in self.terms.iter() {
            match env.get_id(slot) {
                Some(v) => acc += k * v,
                None => {
                    return Err(format!(
                        "unbound parameter '{}'",
                        Sym::from_id(slot)
                    ))
                }
            }
        }
        Ok(acc)
    }
}

/// Compiled multiplicative atom.
#[derive(Clone, Debug)]
enum AtomTape {
    /// bare parameter slot
    Param(u32),
    /// `floor(lin / den)`
    FloorDiv(LinTape, i64),
}

impl AtomTape {
    fn compile(a: &Atom) -> AtomTape {
        match a {
            Atom::Param(s) => AtomTape::Param(s.id()),
            Atom::FloorDiv(num, den) => AtomTape::FloorDiv(LinTape::compile(num), *den),
        }
    }

    #[inline]
    fn eval(&self, env: &Env) -> Result<i64, String> {
        match self {
            AtomTape::Param(slot) => env.get_id(*slot).ok_or_else(|| {
                format!("unbound parameter '{}'", Sym::from_id(*slot))
            }),
            AtomTape::FloorDiv(lin, den) => Ok(lin.eval(env)?.div_euclid(*den)),
        }
    }
}

/// Compiled quasi-polynomial: unique atoms are evaluated once into a
/// scratch frame, then terms multiply slot-indexed factors.
#[derive(Clone, Debug, Default)]
pub struct PolyTape {
    atoms: Box<[AtomTape]>,
    term_coeff: Box<[f64]>,
    /// factor-range offsets per term; `len == term_coeff.len() + 1`
    term_off: Box<[u32]>,
    /// `(atom index, exponent)` factor pool
    factors: Box<[(u32, u32)]>,
}

impl PolyTape {
    pub fn compile(q: &QPoly) -> PolyTape {
        let mut atoms: Vec<AtomTape> = Vec::new();
        let mut atom_index: Vec<(&Atom, u32)> = Vec::new();
        let mut term_coeff = Vec::with_capacity(q.terms.len());
        let mut term_off = vec![0u32];
        let mut factors = Vec::new();
        for (m, c) in &q.terms {
            term_coeff.push(*c);
            for (atom, e) in m {
                let ai = match atom_index.iter().find(|(a, _)| *a == atom) {
                    Some((_, i)) => *i,
                    None => {
                        let i = atoms.len() as u32;
                        atoms.push(AtomTape::compile(atom));
                        atom_index.push((atom, i));
                        i
                    }
                };
                factors.push((ai, *e));
            }
            term_off.push(factors.len() as u32);
        }
        PolyTape {
            atoms: atoms.into(),
            term_coeff: term_coeff.into(),
            term_off: term_off.into(),
            factors: factors.into(),
        }
    }

    /// Evaluate with caller-provided atom scratch (cleared internally).
    pub fn eval_with(&self, env: &Env, atom_vals: &mut Vec<f64>) -> Result<f64, String> {
        atom_vals.clear();
        for a in self.atoms.iter() {
            atom_vals.push(a.eval(env)? as f64);
        }
        let mut acc = 0.0;
        for t in 0..self.term_coeff.len() {
            let mut term = self.term_coeff[t];
            let lo = self.term_off[t] as usize;
            let hi = self.term_off[t + 1] as usize;
            for &(ai, e) in &self.factors[lo..hi] {
                let v = atom_vals[ai as usize];
                term *= if e == 1 { v } else { v.powi(e as i32) };
            }
            acc += term;
        }
        Ok(acc)
    }
}

/// Compiled piecewise quasi-polynomial: guards as [`LinTape`]s, pieces
/// evaluated first-match, 0 when no guard set holds.
#[derive(Clone, Debug, Default)]
pub struct PwTape {
    pieces: Box<[(Box<[LinTape]>, PolyTape)]>,
}

thread_local! {
    static ATOM_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

impl PwTape {
    pub fn compile(p: &PwQPoly) -> PwTape {
        PwTape {
            pieces: p
                .pieces
                .iter()
                .map(|(guards, q)| {
                    (
                        guards
                            .iter()
                            .map(|g| LinTape::compile(&g.0))
                            .collect::<Box<[LinTape]>>(),
                        PolyTape::compile(q),
                    )
                })
                .collect(),
        }
    }

    /// Allocation-free evaluation (scratch is a thread-local buffer).
    pub fn eval(&self, env: &Env) -> Result<f64, String> {
        ATOM_SCRATCH.with(|scratch| {
            let mut buf = scratch.borrow_mut();
            self.eval_with(env, &mut buf)
        })
    }

    /// Evaluate with caller-provided scratch (for callers that manage
    /// their own buffers).
    pub fn eval_with(&self, env: &Env, atom_vals: &mut Vec<f64>) -> Result<f64, String> {
        'piece: for (guards, poly) in self.pieces.iter() {
            for g in guards.iter() {
                if g.eval(env)? < 0 {
                    continue 'piece;
                }
            }
            return poly.eval_with(env, atom_vals);
        }
        Ok(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qpoly::{env, Guard};

    #[test]
    fn lintape_matches_linexpr() {
        let e = LinExpr::var("n").scale(3).add(&LinExpr::var("m").scale(-2)).add(&LinExpr::constant(7));
        let t = LinTape::compile(&e);
        let b = env(&[("n", 11), ("m", 5)]);
        assert_eq!(t.eval(&b).unwrap(), e.eval(&b).unwrap());
        assert!(t.eval(&env(&[("n", 1)])).is_err());
    }

    #[test]
    fn polytape_matches_qpoly() {
        // (n*m + 2n + 1) * floor(n/2)
        let p = QPoly::param("n")
            .mul(&QPoly::param("m"))
            .add(&QPoly::param("n").scale(2.0))
            .add(&QPoly::one())
            .mul(&QPoly::from_atom(Atom::FloorDiv(LinExpr::var("n"), 2)));
        let t = PolyTape::compile(&p);
        let mut scratch = Vec::new();
        for (n, m) in [(9i64, 4i64), (0, 0), (100, 3), (7, 7)] {
            let b = env(&[("n", n), ("m", m)]);
            assert_eq!(
                t.eval_with(&b, &mut scratch).unwrap(),
                p.eval(&b).unwrap(),
                "n={n} m={m}"
            );
        }
    }

    #[test]
    fn pwtape_respects_guards_and_default_zero() {
        let pw = PwQPoly {
            pieces: vec![(
                vec![Guard(LinExpr::var("n").sub(&LinExpr::constant(4)))],
                QPoly::param("n").mul(&QPoly::param("n")),
            )],
        };
        let t = PwTape::compile(&pw);
        assert_eq!(t.eval(&env(&[("n", 8)])).unwrap(), 64.0);
        assert_eq!(t.eval(&env(&[("n", 2)])).unwrap(), 0.0);
        assert!(t.eval(&env(&[])).is_err());
    }

    #[test]
    fn tape_reeval_over_sweep_matches() {
        let q = QPoly::param("n")
            .mul(&QPoly::param("n"))
            .add(&QPoly::from_atom(Atom::FloorDiv(
                LinExpr::var("n").add(&LinExpr::constant(15)),
                16,
            )));
        let pw = PwQPoly::from_qpoly(q.clone());
        let t = PwTape::compile(&pw);
        for n in 0..200 {
            let b = env(&[("n", n)]);
            assert_eq!(t.eval(&b).unwrap(), q.eval(&b).unwrap(), "n={n}");
        }
    }
}
