//! Compiled evaluation tapes for affine expressions and (piecewise)
//! quasi-polynomials.
//!
//! Symbolic property counts are built once per kernel and then
//! re-evaluated for many parameter bindings (size sweeps, the
//! measurement campaign, autotuning loops, prediction serving). The
//! tree-walking evaluators in [`crate::qpoly`] are exact but chase
//! `BTreeMap` nodes on every call; this module flattens an expression
//! into contiguous arrays of slot-indexed operations that evaluate with
//! a single linear pass over the tape and O(1) [`Env`] slot loads — no
//! string comparison, no map probing, no per-eval allocation (atom
//! scratch lives in a thread-local buffer).
//!
//! For batched workloads ([`crate::engine`]'s `predict_batch` /
//! `predict_matrix`, the measurement campaign) the `eval_many` entry
//! points walk each tape instruction *once* across N environments laid
//! out as a structure-of-arrays [`EnvFrame`]: per-slot value columns are
//! contiguous, so the per-term floating-point inner loops run over flat
//! `f64` columns the compiler can vectorize. Integer affine arithmetic
//! is checked (overflow is an `Err`, never a wrapped count), and a
//! batch fails as a whole on the first lane error — callers that need
//! per-request attribution fall back to the scalar path, which by
//! construction produces the identical diagnostic.
//!
//! Compilation preserves the exact term/atom/guard ordering of the
//! source object, so tape evaluation — scalar and batched — is
//! bit-identical to the tree-walking path (verified by property tests
//! in `rust/tests/properties.rs`).

use super::{Atom, LinExpr, PwQPoly, QPoly};
use crate::util::intern::{Env, Sym};
use std::cell::RefCell;

#[cold]
fn unbound(slot: u32) -> String {
    format!("unbound parameter '{}'", Sym::from_id(slot))
}

/// Structure-of-arrays view of N environments: one contiguous value
/// column per interned slot, lane `j` holding environment `j`'s binding.
///
/// Layout is slot-major — `vals[slot * n_envs + j]` — so a tape term
/// touching one symbol streams a single contiguous column. Buffers are
/// reused across `load` calls; the frame grows to the high-water mark
/// and never shrinks.
#[derive(Default)]
pub struct EnvFrame {
    n_envs: usize,
    n_slots: usize,
    vals: Vec<i64>,
    bound: Vec<bool>,
}

impl EnvFrame {
    pub fn new() -> EnvFrame {
        EnvFrame::default()
    }

    /// (Re)fill the frame from `envs`. Lane `j` mirrors `envs[j]`.
    pub fn load(&mut self, envs: &[&Env]) {
        self.n_envs = envs.len();
        self.n_slots = envs.iter().map(|e| e.slot_width()).max().unwrap_or(0);
        let cells = self.n_slots * self.n_envs;
        self.vals.clear();
        self.vals.resize(cells, 0);
        self.bound.clear();
        self.bound.resize(cells, false);
        for (j, e) in envs.iter().enumerate() {
            for (sym, v) in e.iter() {
                let i = sym.id() as usize * self.n_envs + j;
                self.vals[i] = v;
                self.bound[i] = true;
            }
        }
    }

    pub fn n_envs(&self) -> usize {
        self.n_envs
    }

    /// Value and bound-flag columns for a slot; `None` when the slot is
    /// beyond every loaded environment (i.e. unbound in all lanes).
    #[inline]
    fn col(&self, slot: u32) -> Option<(&[i64], &[bool])> {
        let s = slot as usize;
        if s >= self.n_slots {
            return None;
        }
        let lo = s * self.n_envs;
        let hi = lo + self.n_envs;
        Some((&self.vals[lo..hi], &self.bound[lo..hi]))
    }

    /// Lane-scalar access: the value bound to `slot` in environment
    /// `lane`, if any.
    #[inline]
    pub fn get(&self, slot: u32, lane: usize) -> Option<i64> {
        let (vals, bound) = self.col(slot)?;
        if bound[lane] {
            Some(vals[lane])
        } else {
            None
        }
    }
}

/// Reusable scratch for batched tape evaluation. One instance serves any
/// number of `eval_many` calls; buffers grow to the high-water mark and
/// are never shrunk. Nothing here carries state between calls.
#[derive(Default)]
pub struct TapeScratch {
    /// selected piece index per lane (`u32::MAX` = no guard held)
    piece: Vec<u32>,
    /// slot-major atom value columns, `atom_cols[ai * n_envs + lane]`
    atom_cols: Vec<f64>,
    /// i64 column for affine sub-evaluations (floor-division numerators)
    ints: Vec<i64>,
    /// per-term product column
    tmp: Vec<f64>,
    /// atom scratch for the lane-scalar mixed-piece fallback
    lane_atoms: Vec<f64>,
}

impl TapeScratch {
    pub fn new() -> TapeScratch {
        TapeScratch::default()
    }
}

/// Compiled affine expression: `c + Σ coeff · frame[slot]`.
#[derive(Clone, Debug, Default)]
pub struct LinTape {
    pub c: i64,
    /// `(symbol slot id, coefficient)` pairs in symbol order
    pub terms: Box<[(u32, i64)]>,
}

impl LinTape {
    pub fn compile(e: &LinExpr) -> LinTape {
        LinTape {
            c: e.c,
            terms: e.terms.iter().map(|(s, k)| (s.id(), *k)).collect(),
        }
    }

    /// Evaluate against a slot frame; errors on unbound slots and on
    /// `i64` overflow.
    #[inline]
    pub fn eval(&self, env: &Env) -> Result<i64, String> {
        let mut acc = self.c;
        for &(slot, k) in self.terms.iter() {
            match env.get_id(slot) {
                Some(v) => acc = super::checked_term(acc, k, v, Sym::from_id(slot))?,
                None => return Err(unbound(slot)),
            }
        }
        Ok(acc)
    }

    /// Batched evaluation: one pass over the tape, all lanes per term.
    /// Fails the whole batch on the first lane error.
    pub fn eval_many(&self, frame: &EnvFrame, out: &mut [i64]) -> Result<(), String> {
        debug_assert_eq!(out.len(), frame.n_envs());
        out.fill(self.c);
        for &(slot, k) in self.terms.iter() {
            let sym = Sym::from_id(slot);
            let Some((vals, bound)) = frame.col(slot) else {
                return Err(unbound(slot));
            };
            for ((o, &v), &b) in out.iter_mut().zip(vals).zip(bound) {
                if !b {
                    return Err(unbound(slot));
                }
                *o = super::checked_term(*o, k, v, sym)?;
            }
        }
        Ok(())
    }

    /// Scalar evaluation of a single frame lane (guard checks).
    #[inline]
    fn eval_lane(&self, frame: &EnvFrame, lane: usize) -> Result<i64, String> {
        let mut acc = self.c;
        for &(slot, k) in self.terms.iter() {
            match frame.get(slot, lane) {
                Some(v) => acc = super::checked_term(acc, k, v, Sym::from_id(slot))?,
                None => return Err(unbound(slot)),
            }
        }
        Ok(acc)
    }
}

/// Compiled multiplicative atom.
#[derive(Clone, Debug)]
enum AtomTape {
    /// bare parameter slot
    Param(u32),
    /// `floor(lin / den)`
    FloorDiv(LinTape, i64),
}

impl AtomTape {
    fn compile(a: &Atom) -> AtomTape {
        match a {
            Atom::Param(s) => AtomTape::Param(s.id()),
            Atom::FloorDiv(num, den) => AtomTape::FloorDiv(LinTape::compile(num), *den),
        }
    }

    #[inline]
    fn eval(&self, env: &Env) -> Result<i64, String> {
        match self {
            AtomTape::Param(slot) => env.get_id(*slot).ok_or_else(|| unbound(*slot)),
            AtomTape::FloorDiv(lin, den) => super::checked_floordiv(lin.eval(env)?, *den),
        }
    }

    #[inline]
    fn eval_lane(&self, frame: &EnvFrame, lane: usize) -> Result<i64, String> {
        match self {
            AtomTape::Param(slot) => frame.get(*slot, lane).ok_or_else(|| unbound(*slot)),
            AtomTape::FloorDiv(lin, den) => {
                super::checked_floordiv(lin.eval_lane(frame, lane)?, *den)
            }
        }
    }
}

/// Compiled quasi-polynomial: unique atoms are evaluated once into a
/// scratch frame, then terms multiply slot-indexed factors.
#[derive(Clone, Debug, Default)]
pub struct PolyTape {
    atoms: Box<[AtomTape]>,
    term_coeff: Box<[f64]>,
    /// factor-range offsets per term; `len == term_coeff.len() + 1`
    term_off: Box<[u32]>,
    /// `(atom index, exponent)` factor pool
    factors: Box<[(u32, u32)]>,
}

impl PolyTape {
    pub fn compile(q: &QPoly) -> PolyTape {
        let mut atoms: Vec<AtomTape> = Vec::new();
        let mut atom_index: Vec<(&Atom, u32)> = Vec::new();
        let mut term_coeff = Vec::with_capacity(q.terms.len());
        let mut term_off = vec![0u32];
        let mut factors = Vec::new();
        for (m, c) in &q.terms {
            term_coeff.push(*c);
            for (atom, e) in m {
                let ai = match atom_index.iter().find(|(a, _)| *a == atom) {
                    Some((_, i)) => *i,
                    None => {
                        let i = atoms.len() as u32;
                        atoms.push(AtomTape::compile(atom));
                        atom_index.push((atom, i));
                        i
                    }
                };
                factors.push((ai, *e));
            }
            term_off.push(factors.len() as u32);
        }
        PolyTape {
            atoms: atoms.into(),
            term_coeff: term_coeff.into(),
            term_off: term_off.into(),
            factors: factors.into(),
        }
    }

    /// Sum terms over pre-evaluated atom values. Shared by every entry
    /// point so the floating-point operation order — and therefore the
    /// bit pattern of the result — is identical across scalar and
    /// batched evaluation.
    #[inline]
    fn sum_terms(&self, atom_vals: &[f64]) -> f64 {
        let mut acc = 0.0;
        for t in 0..self.term_coeff.len() {
            let mut term = self.term_coeff[t];
            let lo = self.term_off[t] as usize;
            let hi = self.term_off[t + 1] as usize;
            for &(ai, e) in &self.factors[lo..hi] {
                let v = atom_vals[ai as usize];
                term *= if e == 1 { v } else { v.powi(e as i32) };
            }
            acc += term;
        }
        acc
    }

    /// Evaluate with caller-provided atom scratch (cleared internally).
    pub fn eval_with(&self, env: &Env, atom_vals: &mut Vec<f64>) -> Result<f64, String> {
        atom_vals.clear();
        for a in self.atoms.iter() {
            atom_vals.push(a.eval(env)? as f64);
        }
        Ok(self.sum_terms(atom_vals))
    }

    /// Scalar evaluation of a single frame lane.
    fn eval_lane(
        &self,
        frame: &EnvFrame,
        lane: usize,
        atom_vals: &mut Vec<f64>,
    ) -> Result<f64, String> {
        atom_vals.clear();
        for a in self.atoms.iter() {
            atom_vals.push(a.eval_lane(frame, lane)? as f64);
        }
        Ok(self.sum_terms(atom_vals))
    }

    /// Batched evaluation: atoms become contiguous value columns, then
    /// each term's coefficient/factor multiplies stream over whole
    /// columns at once. Per-lane operation order matches [`Self::eval_with`]
    /// exactly, so results are bit-identical lane by lane.
    pub fn eval_many(
        &self,
        frame: &EnvFrame,
        scratch: &mut TapeScratch,
        out: &mut [f64],
    ) -> Result<(), String> {
        let n = frame.n_envs();
        debug_assert_eq!(out.len(), n);
        let na = self.atoms.len();
        scratch.atom_cols.clear();
        scratch.atom_cols.resize(na * n, 0.0);
        scratch.ints.clear();
        scratch.ints.resize(n, 0);
        for (ai, a) in self.atoms.iter().enumerate() {
            let col = &mut scratch.atom_cols[ai * n..(ai + 1) * n];
            match a {
                AtomTape::Param(slot) => {
                    let Some((vals, bound)) = frame.col(*slot) else {
                        return Err(unbound(*slot));
                    };
                    for ((c, &v), &b) in col.iter_mut().zip(vals).zip(bound) {
                        if !b {
                            return Err(unbound(*slot));
                        }
                        *c = v as f64;
                    }
                }
                AtomTape::FloorDiv(lin, den) => {
                    lin.eval_many(frame, &mut scratch.ints)?;
                    for (c, &v) in col.iter_mut().zip(scratch.ints.iter()) {
                        *c = super::checked_floordiv(v, *den)? as f64;
                    }
                }
            }
        }
        out.fill(0.0);
        scratch.tmp.clear();
        scratch.tmp.resize(n, 0.0);
        for t in 0..self.term_coeff.len() {
            scratch.tmp.fill(self.term_coeff[t]);
            let lo = self.term_off[t] as usize;
            let hi = self.term_off[t + 1] as usize;
            for &(ai, e) in &self.factors[lo..hi] {
                let col = &scratch.atom_cols[ai as usize * n..(ai as usize + 1) * n];
                if e == 1 {
                    for (tv, &v) in scratch.tmp.iter_mut().zip(col) {
                        *tv *= v;
                    }
                } else {
                    for (tv, &v) in scratch.tmp.iter_mut().zip(col) {
                        *tv *= v.powi(e as i32);
                    }
                }
            }
            for (o, &tv) in out.iter_mut().zip(scratch.tmp.iter()) {
                *o += tv;
            }
        }
        Ok(())
    }
}

/// Compiled piecewise quasi-polynomial: guards as [`LinTape`]s, pieces
/// evaluated first-match, 0 when no guard set holds.
#[derive(Clone, Debug, Default)]
pub struct PwTape {
    pieces: Box<[(Box<[LinTape]>, PolyTape)]>,
}

thread_local! {
    static ATOM_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

impl PwTape {
    pub fn compile(p: &PwQPoly) -> PwTape {
        PwTape {
            pieces: p
                .pieces
                .iter()
                .map(|(guards, q)| {
                    (
                        guards
                            .iter()
                            .map(|g| LinTape::compile(&g.0))
                            .collect::<Box<[LinTape]>>(),
                        PolyTape::compile(q),
                    )
                })
                .collect(),
        }
    }

    /// Allocation-free evaluation (scratch is a thread-local buffer).
    ///
    /// Re-entrant evaluation on the same thread — e.g. a callback that
    /// itself predicts while a prediction is on the stack — finds the
    /// thread-local busy and degrades to a fresh local buffer instead of
    /// panicking the worker on a `BorrowMutError`.
    pub fn eval(&self, env: &Env) -> Result<f64, String> {
        ATOM_SCRATCH.with(|scratch| match scratch.try_borrow_mut() {
            Ok(mut buf) => self.eval_with(env, &mut buf),
            Err(_) => self.eval_with(env, &mut Vec::new()),
        })
    }

    /// Evaluate with caller-provided scratch (for callers that manage
    /// their own buffers).
    pub fn eval_with(&self, env: &Env, atom_vals: &mut Vec<f64>) -> Result<f64, String> {
        'piece: for (guards, poly) in self.pieces.iter() {
            for g in guards.iter() {
                if g.eval(env)? < 0 {
                    continue 'piece;
                }
            }
            return poly.eval_with(env, atom_vals);
        }
        Ok(0.0)
    }

    /// Batched evaluation over an [`EnvFrame`]: piece selection runs
    /// per lane (guards are tiny affine tapes), then — in the common
    /// case where every lane lands on the same piece — the polynomial
    /// streams over whole columns in one pass. Mixed-piece batches
    /// degrade to lane-scalar evaluation of each selected piece.
    ///
    /// Fails the whole batch on the first lane error (unbound parameter
    /// or `i64` overflow); callers needing per-lane attribution fall
    /// back to scalar [`Self::eval`], which produces the identical
    /// diagnostic.
    pub fn eval_many(
        &self,
        frame: &EnvFrame,
        scratch: &mut TapeScratch,
        out: &mut [f64],
    ) -> Result<(), String> {
        const NONE: u32 = u32::MAX;
        let n = frame.n_envs();
        debug_assert_eq!(out.len(), n);
        scratch.piece.clear();
        scratch.piece.resize(n, NONE);
        for (lane, sel) in scratch.piece.iter_mut().enumerate() {
            'piece: for (pi, (guards, _)) in self.pieces.iter().enumerate() {
                for g in guards.iter() {
                    if g.eval_lane(frame, lane)? < 0 {
                        continue 'piece;
                    }
                }
                *sel = pi as u32;
                break;
            }
        }
        if n > 0 {
            let first = scratch.piece[0];
            if scratch.piece.iter().all(|&p| p == first) {
                if first == NONE {
                    out.fill(0.0);
                    return Ok(());
                }
                return self.pieces[first as usize].1.eval_many(frame, scratch, out);
            }
        }
        for (lane, o) in out.iter_mut().enumerate() {
            *o = match scratch.piece[lane] {
                NONE => 0.0,
                pi => self.pieces[pi as usize]
                    .1
                    .eval_lane(frame, lane, &mut scratch.lane_atoms)?,
            };
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qpoly::{env, Guard};

    #[test]
    fn lintape_matches_linexpr() {
        let e = LinExpr::var("n").scale(3).add(&LinExpr::var("m").scale(-2)).add(&LinExpr::constant(7));
        let t = LinTape::compile(&e);
        let b = env(&[("n", 11), ("m", 5)]);
        assert_eq!(t.eval(&b).unwrap(), e.eval(&b).unwrap());
        assert!(t.eval(&env(&[("n", 1)])).is_err());
    }

    #[test]
    fn polytape_matches_qpoly() {
        // (n*m + 2n + 1) * floor(n/2)
        let p = QPoly::param("n")
            .mul(&QPoly::param("m"))
            .add(&QPoly::param("n").scale(2.0))
            .add(&QPoly::one())
            .mul(&QPoly::from_atom(Atom::FloorDiv(LinExpr::var("n"), 2)));
        let t = PolyTape::compile(&p);
        let mut scratch = Vec::new();
        for (n, m) in [(9i64, 4i64), (0, 0), (100, 3), (7, 7)] {
            let b = env(&[("n", n), ("m", m)]);
            assert_eq!(
                t.eval_with(&b, &mut scratch).unwrap(),
                p.eval(&b).unwrap(),
                "n={n} m={m}"
            );
        }
    }

    #[test]
    fn pwtape_respects_guards_and_default_zero() {
        let pw = PwQPoly {
            pieces: vec![(
                vec![Guard(LinExpr::var("n").sub(&LinExpr::constant(4)))],
                QPoly::param("n").mul(&QPoly::param("n")),
            )],
        };
        let t = PwTape::compile(&pw);
        assert_eq!(t.eval(&env(&[("n", 8)])).unwrap(), 64.0);
        assert_eq!(t.eval(&env(&[("n", 2)])).unwrap(), 0.0);
        assert!(t.eval(&env(&[])).is_err());
    }

    #[test]
    fn tape_reeval_over_sweep_matches() {
        let q = QPoly::param("n")
            .mul(&QPoly::param("n"))
            .add(&QPoly::from_atom(Atom::FloorDiv(
                LinExpr::var("n").add(&LinExpr::constant(15)),
                16,
            )));
        let pw = PwQPoly::from_qpoly(q.clone());
        let t = PwTape::compile(&pw);
        for n in 0..200 {
            let b = env(&[("n", n)]);
            assert_eq!(t.eval(&b).unwrap(), q.eval(&b).unwrap(), "n={n}");
        }
    }

    #[test]
    fn pwtape_eval_survives_reentrant_scratch_borrow() {
        // Regression: `eval` used `borrow_mut()` on the thread-local
        // scratch and panicked on any re-entrant evaluation. Holding the
        // borrow here simulates an evaluation already on the stack.
        let pw = PwQPoly::from_qpoly(QPoly::param("n").mul(&QPoly::param("n")));
        let t = PwTape::compile(&pw);
        let b = env(&[("n", 6)]);
        ATOM_SCRATCH.with(|s| {
            let _held = s.borrow_mut();
            assert_eq!(t.eval(&b).unwrap(), 36.0);
        });
    }

    #[test]
    fn tape_overflow_matches_tree_error() {
        let e = LinExpr::scaled_var("n", 3);
        let t = LinTape::compile(&e);
        let b = env(&[("n", i64::MAX / 2)]);
        let tree = e.eval(&b).unwrap_err();
        let tape = t.eval(&b).unwrap_err();
        assert_eq!(tree, tape);
        assert!(tree.contains("overflow"), "{tree}");
    }

    #[test]
    fn eval_many_matches_scalar_eval_bitwise() {
        // Mixed piece selection: the guard n-4 >= 0 fails for the first
        // lanes, which fall through to the unguarded second piece.
        let pw = PwQPoly {
            pieces: vec![
                (
                    vec![Guard(LinExpr::var("n").sub(&LinExpr::constant(4)))],
                    QPoly::param("n").mul(&QPoly::param("m")).add(
                        &QPoly::from_atom(Atom::FloorDiv(
                            LinExpr::var("n").add(&LinExpr::constant(15)),
                            16,
                        ))
                        .scale(3.0),
                    ),
                ),
                (Vec::new(), QPoly::param("m").scale(0.5)),
            ],
        };
        let t = PwTape::compile(&pw);
        let envs: Vec<Env> = (0..17).map(|i| env(&[("n", i * 3 - 2), ("m", 100 - i)])).collect();
        let refs: Vec<&Env> = envs.iter().collect();
        let mut frame = EnvFrame::new();
        frame.load(&refs);
        let mut scratch = TapeScratch::new();
        let mut out = vec![0.0; refs.len()];
        t.eval_many(&frame, &mut scratch, &mut out).unwrap();
        for (j, e) in envs.iter().enumerate() {
            let want = t.eval(e).unwrap();
            assert_eq!(out[j].to_bits(), want.to_bits(), "lane {j}: {} != {want}", out[j]);
        }
        // Uniform batch takes the single-piece SoA fast path; results
        // must still match the scalar walk bit for bit.
        let uni: Vec<Env> = (0..9).map(|i| env(&[("n", 10 + i), ("m", 3 * i)])).collect();
        let urefs: Vec<&Env> = uni.iter().collect();
        frame.load(&urefs);
        let mut uout = vec![0.0; urefs.len()];
        t.eval_many(&frame, &mut scratch, &mut uout).unwrap();
        for (j, e) in uni.iter().enumerate() {
            assert_eq!(uout[j].to_bits(), t.eval(e).unwrap().to_bits(), "lane {j}");
        }
    }

    #[test]
    fn eval_many_fails_whole_batch_on_lane_error() {
        let pw = PwQPoly::from_qpoly(QPoly::param("n"));
        let t = PwTape::compile(&pw);
        let good = env(&[("n", 1)]);
        let bad = env(&[("m", 1)]); // 'n' unbound
        let refs = [&good, &bad];
        let mut frame = EnvFrame::new();
        frame.load(&refs);
        let mut scratch = TapeScratch::new();
        let mut out = [0.0; 2];
        let err = t.eval_many(&frame, &mut scratch, &mut out).unwrap_err();
        assert_eq!(err, t.eval(&bad).unwrap_err());

        // Overflow in one lane also fails the batch, with the scalar
        // path's exact diagnostic (i64 arithmetic lives in the affine
        // floor-division numerator).
        let big = PwQPoly::from_qpoly(QPoly::from_atom(Atom::FloorDiv(
            LinExpr::scaled_var("n", 3),
            2,
        )));
        let tb = PwTape::compile(&big);
        let huge = env(&[("n", i64::MAX / 2)]);
        let refs = [&good, &huge];
        frame.load(&refs);
        let err = tb.eval_many(&frame, &mut scratch, &mut out).unwrap_err();
        assert_eq!(err, tb.eval(&huge).unwrap_err());
        assert!(err.contains("overflow"), "{err}");
    }
}
