"""AOT smoke tests: lowering produces parseable HLO text with the agreed
entry shapes, and the artifacts land where the Makefile expects."""

import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402


def test_build_produces_both_artifacts():
    with tempfile.TemporaryDirectory() as d:
        aot.build(d)
        for name in ("fit.hlo.txt", "predict.hlo.txt"):
            path = os.path.join(d, name)
            assert os.path.exists(path), name
            text = open(path).read()
            assert text.startswith("HloModule"), f"{name} is not HLO text"
            # f64 inputs of the agreed shapes must appear in the signature
            assert "f64[" in text, name


def test_fit_hlo_mentions_padded_shapes():
    with tempfile.TemporaryDirectory() as d:
        aot.build(d)
        text = open(os.path.join(d, "fit.hlo.txt")).read()
        assert f"f64[{model.MAX_CASES},{model.MAX_PROPS}]" in text
        text = open(os.path.join(d, "predict.hlo.txt")).read()
        assert f"f64[{model.MAX_BATCH},{model.MAX_PROPS}]" in text
