"""L2 correctness: the fit computation recovers known weights and matches
both the pure-jnp reference and numpy's lstsq on active columns."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def padded_problem(n_cases, n_active, true_w, noise, seed):
    """Build a (MAX_CASES, MAX_PROPS) padded B with known generating
    weights in the first `n_active` columns."""
    rng = np.random.default_rng(seed)
    big_b = np.zeros((model.MAX_CASES, model.MAX_PROPS))
    rowmask = np.zeros(model.MAX_CASES)
    for i in range(n_cases):
        props = rng.integers(1, 1000, n_active) * 1000.0
        t = float(props @ true_w) * float(np.exp(noise * rng.standard_normal()))
        big_b[i, :n_active] = props / t
        rowmask[i] = 1.0
    return jnp.asarray(big_b), jnp.asarray(rowmask)


def test_fit_recovers_exact_weights():
    true_w = np.array([1e-9, 5e-10, 2e-8])
    big_b, rowmask = padded_problem(40, 3, true_w, 0.0, 3)
    (w,) = model.fit(big_b, rowmask)
    w = np.asarray(w)
    np.testing.assert_allclose(w[:3], true_w, rtol=1e-6)
    assert np.all(w[3:] == 0.0), "inactive columns must get zero weight"


@settings(max_examples=10, deadline=None)
@given(
    n_active=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_fit_matches_reference(n_active, seed):
    rng = np.random.default_rng(seed)
    true_w = rng.uniform(1e-12, 1e-8, n_active)
    big_b, rowmask = padded_problem(64, n_active, true_w, 0.02, seed)
    (w,) = model.fit(big_b, rowmask)
    w_ref = ref.fit_ref(big_b, rowmask, ridge=model.RIDGE)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=1e-8, atol=1e-18)


def test_fit_matches_numpy_lstsq():
    true_w = np.array([2e-12, 8e-12, 3e-9, 1e-4])
    big_b, rowmask = padded_problem(100, 4, true_w, 0.05, 11)
    (w,) = model.fit(big_b, rowmask)
    bnp = np.asarray(big_b)[np.asarray(rowmask) > 0][:, :4]
    w_np, *_ = np.linalg.lstsq(bnp, np.ones(bnp.shape[0]), rcond=None)
    np.testing.assert_allclose(np.asarray(w)[:4], w_np, rtol=1e-4)


def test_padded_rows_are_ignored():
    true_w = np.array([1e-9, 2e-9])
    big_b, rowmask = padded_problem(30, 2, true_w, 0.0, 5)
    # poison the padded region; the rowmask must exclude it
    poisoned = np.asarray(big_b).copy()
    poisoned[31:, :2] = 1e30
    (w_poisoned,) = model.fit(jnp.asarray(poisoned), rowmask)
    (w_clean,) = model.fit(big_b, rowmask)
    np.testing.assert_allclose(np.asarray(w_poisoned), np.asarray(w_clean), rtol=1e-10)


def test_predict_shapes_and_values():
    p = np.zeros((model.MAX_BATCH, model.MAX_PROPS))
    p[0, 0] = 2e9
    p[1, 1] = 3e9
    w = np.zeros(model.MAX_PROPS)
    w[0] = 1e-12
    w[1] = 2e-12
    (out,) = model.predict(jnp.asarray(p), jnp.asarray(w))
    assert out.shape == (model.MAX_BATCH,)
    np.testing.assert_allclose(np.asarray(out)[:2], [2e-3, 6e-3], rtol=1e-12)
