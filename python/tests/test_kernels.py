"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes and data; every case asserts allclose. This is
the core correctness signal for the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import gram, predict, ref  # noqa: E402

TILE = gram.TILE


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype=jnp.float64)


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    props=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_gram_matches_ref(tiles, props, seed):
    n = tiles * TILE
    bs = rand((n, props), seed)
    mask = jnp.asarray(np.random.default_rng(seed + 1).integers(0, 2, n), dtype=jnp.float64)
    g, atb = gram.gram(bs, mask)
    g_ref, atb_ref = ref.gram_ref(bs, mask)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(atb), np.asarray(atb_ref), rtol=1e-12, atol=1e-12)


def test_gram_rejects_ragged_rows():
    with pytest.raises(AssertionError):
        gram.gram(jnp.zeros((TILE + 1, 4)), jnp.zeros(TILE + 1))


@settings(max_examples=20, deadline=None)
@given(
    batch=st.integers(min_value=1, max_value=64),
    props=st.integers(min_value=1, max_value=160),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_predict_matches_ref(batch, props, seed):
    p = rand((batch, props), seed, scale=1e6)
    w = rand((props,), seed + 7, scale=1e-9)
    out = predict.predict(p, w)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.predict_ref(p, w)), rtol=1e-12
    )


def test_gram_accumulates_across_grid_steps():
    # values differ per tile: accumulation across program ids must be exact
    n, p = 4 * TILE, 8
    bs = jnp.arange(n * p, dtype=jnp.float64).reshape(n, p) / (n * p)
    mask = jnp.ones(n, dtype=jnp.float64)
    g, atb = gram.gram(bs, mask)
    np.testing.assert_allclose(np.asarray(g), np.asarray(bs.T @ bs), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(atb), np.asarray(bs.sum(axis=0)), rtol=1e-12)


def test_predict_f64_precision():
    # weights at 1e-12 scale with counts at 1e9 scale: f64 required
    p = jnp.asarray([[1e9, 2e9, 1.0]], dtype=jnp.float64)
    w = jnp.asarray([1e-12, 5e-13, 1e-4], dtype=jnp.float64)
    out = predict.predict(p, w)
    np.testing.assert_allclose(np.asarray(out), [1e-3 + 1e-3 + 1e-4], rtol=1e-12)
