"""L2: the fit and predict computations (paper section 4.3), built on the
L1 Pallas kernels. Lowered once to HLO text by aot.py; never imported at
run time by the Rust coordinator.

The fixed AOT shapes (padding + masking contracts shared with
rust/src/runtime/mod.rs):

* fit:     B (MAX_CASES, MAX_PROPS) f64, rowmask (MAX_CASES,) f64
           -> weights (MAX_PROPS,) f64
* predict: P (MAX_BATCH, MAX_PROPS) f64, w (MAX_PROPS,) f64
           -> times (MAX_BATCH,) f64

Inactive (all-zero) columns receive zero weights; padded rows are masked
by ``rowmask``. The relative-error scaling (dividing each property row by
its measured time) happens on the Rust side before the call.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import gram as gram_kernel  # noqa: E402
from .kernels import predict as predict_kernel  # noqa: E402

# must match rust/src/runtime/mod.rs
MAX_CASES = 512
MAX_PROPS = 160
MAX_BATCH = 64
RIDGE = 1e-10


def solve_spd(g, b):
    """Gauss-Jordan solve for the (equilibrated, ridge-regularised,
    symmetric positive-definite) normal equations.

    ``jnp.linalg.solve`` lowers to a LAPACK typed-FFI custom-call on CPU,
    which xla_extension 0.5.1 (the Rust runtime) rejects; this loop lowers
    to native HLO (while + dynamic-slice) instead. No pivoting is needed
    for an SPD system with a unit diagonal on inactive columns.
    """
    n = g.shape[0]
    aug = jnp.concatenate([g, b[:, None]], axis=1)  # (n, n+1)

    def body(k, aug):
        row = aug[k] / aug[k, k]
        factors = aug[:, k].at[k].set(0.0)
        aug = aug - factors[:, None] * row[None, :]
        return aug.at[k].set(row)

    aug = jax.lax.fori_loop(0, n, body, aug)
    return aug[:, n]


def fit(big_b, rowmask):
    """Relative-error least squares ``min ||B w - 1||`` with column
    equilibration and a tiny ridge; the Gram-matrix hot spot runs in the
    Pallas kernel."""
    bm = big_b * rowmask[:, None]
    scale = jnp.max(jnp.abs(bm), axis=0)
    active = (scale > 0).astype(big_b.dtype)
    scale_safe = jnp.where(scale > 0, scale, 1.0)
    bs = bm / scale_safe
    g, atb = gram_kernel.gram(bs, rowmask)
    nrows = jnp.sum(rowmask)
    # unit diagonal on inactive columns keeps the system nonsingular
    g = g + jnp.diag(RIDGE * nrows * active + (1.0 - active))
    w = solve_spd(g, atb * active)
    return (w * active / scale_safe,)


def predict(props, weights):
    """Batched model evaluation ``P @ w`` (Pallas matvec)."""
    return (predict_kernel.predict(props, weights),)


def fit_shapes():
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((MAX_CASES, MAX_PROPS), f64),
        jax.ShapeDtypeStruct((MAX_CASES,), f64),
    )


def predict_shapes():
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((MAX_BATCH, MAX_PROPS), f64),
        jax.ShapeDtypeStruct((MAX_PROPS,), f64),
    )
