"""AOT lowering: jax (L2) + Pallas (L1) -> HLO text artifacts for the
Rust PJRT runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowering uses
``return_tuple=True``; the Rust side unwraps the result tuple.

Usage: ``python -m compile.aot --outdir ../artifacts`` (from python/).
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)

    fit_lowered = jax.jit(model.fit).lower(*model.fit_shapes())
    fit_path = os.path.join(outdir, "fit.hlo.txt")
    text = to_hlo_text(fit_lowered)
    with open(fit_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {fit_path}")

    pred_lowered = jax.jit(model.predict).lower(*model.predict_shapes())
    pred_path = os.path.join(outdir, "predict.hlo.txt")
    text = to_hlo_text(pred_lowered)
    with open(pred_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {pred_path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts", help="artifact output directory")
    args = ap.parse_args()
    build(args.outdir)


if __name__ == "__main__":
    main()
