"""L1 Pallas kernel: batched model evaluation.

Prediction is the paper's "rapid evaluation" claim: one inner product per
kernel, ``times = P @ w``. Batched over up to MAX_BATCH property vectors;
a single (B, P) block comfortably fits VMEM, so the kernel is one MXU
matvec. ``interpret=True`` for the CPU build (see gram.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _predict_kernel(p_ref, w_ref, o_ref):
    o_ref[...] = p_ref[...] @ w_ref[...]


def predict(props, weights):
    """``props (B, P) @ weights (P,) -> (B,)``."""
    b, p = props.shape
    assert weights.shape == (p,)
    return pl.pallas_call(
        _predict_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), props.dtype),
        interpret=True,
    )(props, weights)
