"""L1 Pallas kernel: fused Gram matrix + masked column-sum.

The fit's hot spot is forming the normal equations of the scaled property
matrix: ``G = Bs^T Bs`` and ``atb = Bs^T rowmask`` (paper section 4.3). The
kernel tiles the case dimension into ``TILE``-row panels streamed through
the grid; the (properties x properties) accumulator lives in the output
block across grid steps.

TPU mapping (DESIGN.md section Hardware-Adaptation): each panel is a
(TILE, P) VMEM-resident block feeding the MXU via ``blk.T @ blk``;
successive grid steps double-buffer panels from HBM. ``interpret=True``
is mandatory on the CPU build (real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# rows per grid step: one VMEM panel
TILE = 128


def _gram_kernel(b_ref, v_ref, g_ref, a_ref):
    """One panel: accumulate G += blk^T blk, atb += blk^T v."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        a_ref[...] = jnp.zeros_like(a_ref)

    blk = b_ref[...]
    g_ref[...] += blk.T @ blk
    a_ref[...] += blk.T @ v_ref[...]


def gram(bs, rowmask):
    """``(G, atb) = (bs^T bs, bs^T rowmask)`` for a (N, P) matrix.

    ``N`` must be a multiple of :data:`TILE` (the AOT shapes are).
    """
    n, p = bs.shape
    assert n % TILE == 0, f"rows {n} not a multiple of {TILE}"
    grid = n // TILE
    return pl.pallas_call(
        _gram_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((TILE, p), lambda i: (i, 0)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((p, p), lambda i: (0, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, p), bs.dtype),
            jax.ShapeDtypeStruct((p,), bs.dtype),
        ],
        interpret=True,
    )(bs, rowmask)
