"""Pure-jnp oracles for the Pallas kernels (the pytest correctness
signal: pallas-vs-ref allclose)."""

import jax.numpy as jnp


def gram_ref(bs, rowmask):
    """Reference for :func:`..gram.gram`."""
    return bs.T @ bs, bs.T @ rowmask


def predict_ref(props, weights):
    """Reference for :func:`..predict.predict`."""
    return props @ weights


def fit_ref(big_b, rowmask, ridge=1e-10):
    """Reference for the full L2 fit (mirrors model.fit without Pallas):
    column-equilibrated ridge-regularised normal equations."""
    bm = big_b * rowmask[:, None]
    scale = jnp.max(jnp.abs(bm), axis=0)
    active = (scale > 0).astype(big_b.dtype)
    scale_safe = jnp.where(scale > 0, scale, 1.0)
    bs = bm / scale_safe
    g = bs.T @ bs
    atb = bs.T @ rowmask
    nrows = jnp.sum(rowmask)
    g = g + jnp.diag(ridge * nrows * active + (1.0 - active))
    w = jnp.linalg.solve(g, atb * active)
    return w * active / scale_safe
