//! **End-to-end driver**: reproduce the paper's full evaluation (§5) —
//! Table 1 (predicted vs. actual times + geometric-mean relative errors
//! for 4 test kernels × 4 sizes × 4 GPUs) and Table 2 (R9 Fury weights) —
//! on the simulated-GPU substrate, and verify the paper's qualitative
//! claims hold. Results are recorded in EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --example paper_tables`

use uniperf::coordinator::{run_pipeline, Config, FitBackend};
use uniperf::report::render_table2;
use uniperf::stats::Schema;

fn main() {
    let t0 = std::time::Instant::now();
    println!("== Reproducing Table 1 + Table 2 (full pipeline, 4 simulated GPUs) ==\n");
    let cfg = Config {
        backend: FitBackend::Auto,
        out_dir: Some("results".into()),
        ..Config::default()
    };
    let result = run_pipeline(&cfg).expect("pipeline");
    println!("{}", result.table1.render());

    for dr in &result.per_device {
        println!(
            "{:<10} cases={} overhead={:.1}µs train-geomean={:.1}% solver={}",
            dr.device,
            dr.n_measurement_cases,
            dr.launch_overhead_s * 1e6,
            100.0 * dr.model.train_rel_err_geomean,
            dr.model.solver
        );
    }

    // Table 2 for the device the paper shows (R9 Fury)
    let schema = Schema::full();
    if let Some(fury) = result.per_device.iter().find(|d| d.device == "r9_fury") {
        println!("\n== Table 2 (R9 Fury weights) ==\n");
        println!("{}", render_table2(&fury.model, &schema));
    }

    // --- qualitative claims from the paper's §5 -------------------------
    let t1 = &result.table1;
    let mut claims = Vec::new();
    let claim = |name: &str, ok: bool| {
        println!("claim: {:<62} {}", name, if ok { "HOLDS" } else { "DEVIATES" });
        ok
    };
    claims.push(claim(
        "the irregular device (r9_fury) is among the two worst-fitted",
        {
            let mut errs: Vec<(String, f64)> =
                t1.devices().iter().map(|d| (d.clone(), t1.device_err(d))).collect();
            errs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            errs[..2].iter().any(|(d, _)| d == "r9_fury")
        },
    ));
    claims.push(claim(
        "n-body (overlap/occupancy-heavy) is among the two worst kernels",
        {
            let mut errs: Vec<(String, f64)> =
                t1.kernels().iter().map(|k| (k.clone(), t1.kernel_err(k))).collect();
            errs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            errs[..2].iter().any(|(k, _)| k == "nbody")
        },
    ));
    claims.push(claim(
        "fd / skinny-mm predicted with geomean error < 15% cross-GPU",
        t1.kernel_err("fd5") < 0.15 && t1.kernel_err("mm_skinny") < 0.15,
    ));
    claims.push(claim(
        "overall cross-GPU cross-kernel geomean error < 25% (paper: 11%)",
        t1.overall_err() < 0.25,
    ));
    println!(
        "\n{} of {} claims hold; overall geomean {:.2} (paper: 0.11); wall time {:.1}s",
        claims.iter().filter(|&&c| c).count(),
        claims.len(),
        t1.overall_err(),
        t0.elapsed().as_secs_f64()
    );
    println!("results written to results/ (table1.txt, table2_<device>.txt, campaigns, models)");
}
