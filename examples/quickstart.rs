//! Quickstart: the paper's Figure-1 pipeline on a single kernel.
//!
//! 1. Build a kernel in the polyhedral IR (the §3.1 "double a vector"
//!    example, scaled up).
//! 2. Extract its model properties symbolically.
//! 3. Calibrate a device model (measurement campaign + fit).
//! 4. Predict the kernel's run time and compare against the simulated
//!    device — *without* having trained on this kernel.
//!
//! Run with: `cargo run --release --example quickstart`

use uniperf::coordinator::{run_device, Config, FitBackend};
use uniperf::gpusim::SimGpu;
use uniperf::harness::Protocol;
use uniperf::lpir::builder::{gid_lin_1d, KernelBuilder};
use uniperf::lpir::{Access, DType, Expr, Layout};
use uniperf::qpoly::{env, LinExpr};
use uniperf::stats::{extract, ExtractOpts, Schema};

fn main() {
    let device = "k40c";
    println!("== uniperf quickstart on simulated {device} ==\n");

    // --- 1. express a kernel in the IR (out[i] = 2*a[i]) ----------------
    let kernel = KernelBuilder::new("double", &["n"])
        .group_dims_1d(LinExpr::var("n"), 256)
        .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
        .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
        .insn(
            Access::new("out", vec![gid_lin_1d(256)]),
            Expr::mul(Expr::lit(2.0), Expr::load("a", vec![gid_lin_1d(256)])),
            &["g0", "l0"],
            &[],
        )
        .build()
        .expect("kernel builds");
    println!("kernel: out[i] = 2*a[i]  (n threads, 256-lane groups)\n");

    // --- 2. symbolic property extraction ---------------------------------
    let classify_env = env(&[("n", 1 << 22)]);
    let props = extract(&kernel, &classify_env, ExtractOpts::default()).expect("extract");
    println!("extracted properties (symbolic in n):");
    for (label, q) in props.nonzero() {
        println!("  {label:<28} {q}");
    }

    // --- 3. fit the device model (measurement campaign, §4) --------------
    println!("\ncalibrating {device} (390-case measurement campaign)...");
    let schema = Schema::full();
    let cfg = Config {
        devices: vec![device.into()],
        backend: FitBackend::Auto,
        protocol: Protocol::default(),
        ..Config::default()
    };
    let dr = run_device(device, &schema, &cfg).expect("calibration");
    println!(
        "fitted {} weights, training geomean error {:.1}% (solver: {})",
        dr.model.active.len(),
        100.0 * dr.model.train_rel_err_geomean,
        dr.model.solver
    );

    // --- 4. predict vs simulate across sizes -----------------------------
    println!("\n{:<12} {:>12} {:>12} {:>8}", "n", "pred (µs)", "actual (µs)", "relerr");
    let gpu = SimGpu::named(device).unwrap();
    let protocol = Protocol::default();
    for p in [20, 21, 22, 23, 24] {
        let e = env(&[("n", 1i64 << p)]);
        let pred = dr.model.predict_kernel(&schema, &props, &e).expect("predict");
        let times = gpu.time(&kernel, &e, protocol.runs).expect("time");
        let actual = protocol.reduce(&times).expect("reduce");
        println!(
            "2^{p:<10} {:>12.1} {:>12.1} {:>7.1}%",
            pred * 1e6,
            actual * 1e6,
            100.0 * (pred - actual).abs() / actual
        );
    }
    println!("\nquickstart OK");
}
