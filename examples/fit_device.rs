//! Reproduce the paper's **Table 2**: fit the model to one device and
//! print the per-property weights (seconds per operation), directly
//! interpretable and comparable across devices.
//!
//! Run with: `cargo run --release --example fit_device [device]`
//! (default device: r9_fury, as in the paper's Table 2)

use uniperf::coordinator::{run_device, Config, FitBackend};
use uniperf::report::render_table2;
use uniperf::stats::Schema;

fn main() {
    let device = std::env::args().nth(1).unwrap_or_else(|| "r9_fury".to_string());
    println!("== Table 2 reproduction: weight fit for {device} ==\n");
    let schema = Schema::full();
    let cfg = Config {
        devices: vec![device.clone()],
        backend: FitBackend::Auto,
        ..Config::default()
    };
    let dr = run_device(&device, &schema, &cfg).expect("fit");
    println!("{}", render_table2(&dr.model, &schema));
    println!(
        "launch overhead (empty-kernel calibration): {:.1} µs",
        dr.launch_overhead_s * 1e6
    );
    println!("measurement cases used: {}", dr.n_measurement_cases);

    // the paper notes the weights "allow direct conclusions about
    // sustained typical rates": derive a few
    let w = |label: &str| {
        dr.model
            .weight_report(&schema)
            .into_iter()
            .find(|(l, _)| l == label)
            .map(|(_, w)| w)
    };
    if let Some(ws1) = w("f32 stride-1 loads") {
        if ws1 > 0.0 {
            println!(
                "\nimplied sustained stride-1 load bandwidth: {:.0} GB/s",
                4.0 / ws1 / 1e9
            );
        }
    }
    if let Some(wg) = w("thread groups") {
        if wg > 0.0 {
            println!("implied per-group launch cost: {:.2} ns", wg * 1e9);
        }
    }
}
