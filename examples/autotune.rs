//! §6.2 extension: model-guided optimization-variant selection.
//!
//! "Another interesting extension would be to study our model's ability
//! to select the optimal set of kernel configurations (i.e., the set that
//! produces the fastest kernel) from a collection of potential
//! optimizations."
//!
//! This example ranks transpose variants (tiled-prefetch vs. coalesced
//! read vs. coalesced write) and matrix-multiplication variants (tiled
//! vs. naive) by *predicted* time, then checks the ranking against the
//! simulated device — the runtime-autotuning use case the paper
//! motivates.
//!
//! Run with: `cargo run --release --example autotune [device]`

use uniperf::coordinator::{run_device, Config, FitBackend};
use uniperf::gpusim::SimGpu;
use uniperf::harness::{Protocol, PropsCache};
use uniperf::kernels::measure::{mm_naive, mm_tiled, transpose, TransposeVariant};
use uniperf::kernels::KernelCase;
use uniperf::qpoly::env;
use uniperf::stats::{ExtractOpts, Schema};

fn main() {
    let device = std::env::args().nth(1).unwrap_or_else(|| "titan_x".to_string());
    println!("== model-guided variant selection on {device} ==\n");
    let schema = Schema::full();
    let cfg = Config {
        devices: vec![device.clone()],
        backend: FitBackend::Auto,
        ..Config::default()
    };
    let dr = run_device(&device, &schema, &cfg).expect("calibrate");
    let gpu = SimGpu::named(&device).unwrap();
    let protocol = Protocol::default();
    let mut cache = PropsCache::default();

    let mut rank = |title: &str, variants: Vec<KernelCase>| {
        println!("{title}");
        let mut rows: Vec<(String, f64, f64)> = Vec::new();
        for case in variants {
            let props = cache.props_for(&case, ExtractOpts::default()).expect("props");
            let pred = dr.model.predict_kernel(&schema, &props, &case.env).expect("predict");
            let actual =
                protocol
                .reduce(&gpu.time(&case.kernel, &case.env, protocol.runs).expect("time"))
                .expect("reduce");
            rows.push((case.label, pred, actual));
        }
        let mut by_pred = rows.clone();
        by_pred.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let mut by_actual = rows.clone();
        by_actual.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
        for (label, pred, actual) in &rows {
            println!("  {:<28} pred {:>9.3} ms   actual {:>9.3} ms", label, pred * 1e3, actual * 1e3);
        }
        let hit = by_pred[0].0 == by_actual[0].0;
        println!(
            "  model picks: {:<28} truth: {:<28} -> {}\n",
            by_pred[0].0,
            by_actual[0].0,
            if hit { "CORRECT" } else { "MISS" }
        );
        hit
    };

    let n = 2048i64;
    let t_variants = vec![
        KernelCase {
            kernel: transpose(TransposeVariant::Tiled, 16, 16),
            env: env(&[("n", n)]),
            label: "transpose/tiled".into(),
            group: (16, 16),
        },
        KernelCase {
            kernel: transpose(TransposeVariant::CoalescedWrite, 16, 16),
            env: env(&[("n", n)]),
            label: "transpose/coalesced-write".into(),
            group: (16, 16),
        },
        KernelCase {
            kernel: transpose(TransposeVariant::CoalescedRead, 16, 16),
            env: env(&[("n", n)]),
            label: "transpose/coalesced-read".into(),
            group: (16, 16),
        },
    ];
    let hit1 = rank("transpose variants (n=2048):", t_variants);

    let m = 1024i64;
    let mm_variants = vec![
        KernelCase {
            kernel: mm_tiled(16, 16),
            env: env(&[("n", m), ("m", m), ("l", m)]),
            label: "mm/tiled".into(),
            group: (16, 16),
        },
        KernelCase {
            kernel: mm_naive(16, 16),
            env: env(&[("n", m)]),
            label: "mm/naive".into(),
            group: (16, 16),
        },
    ];
    let hit2 = rank("matrix-multiplication variants (n=1024):", mm_variants);

    println!("variant selection: {}/2 families ranked correctly", hit1 as u32 + hit2 as u32);
}
